"""GQA self-attention block with RoPE, optional qk-norm and sliding
window; decode path updates a static-shape KV cache."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (apply_rope, causal_mask_bias, chunked_softmax_attend,
                     dense_init, rms_norm, softmax_attend)
from .sharding_ctx import shard

CHUNKED_THRESHOLD = 2048


def init_gqa(key, cfg: ModelConfig, d_model: Optional[int] = None) -> dict:
    d = d_model or cfg.d_model
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, H * Dh).reshape(d, H, Dh),
         "wk": dense_init(ks[1], d, Hkv * Dh).reshape(d, Hkv, Dh),
         "wv": dense_init(ks[2], d, Hkv * Dh).reshape(d, Hkv, Dh),
         "wo": dense_init(ks[3], H * Dh, d).reshape(H, Dh, d)}
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), jnp.float32)
        p["k_norm"] = jnp.ones((Dh,), jnp.float32)
    return p


def gqa_apply(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
              cfg: ModelConfig, window: int = 0,
              cache: Optional[dict] = None,
              cache_index: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: [B, S, d].  Training/prefill when cache is None; decode
    (S == 1) updates cache at cache_index and attends over it."""
    dt = x.dtype
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)

    if cache is None:
        if S > CHUNKED_THRESHOLD:
            out = chunked_softmax_attend(q, k, v, positions, positions,
                                         window=window)
        else:
            bias = causal_mask_bias(positions, positions, window)
            out = softmax_attend(q, k, v, bias)
        new_cache = None
    else:
        # decode: write new kv at cache_index, attend over whole cache
        idx = cache_index
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
            cache["k"].dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
            cache["v"].dtype), idx, axis=1)
        S_max = ck.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(S_max)[None, :], (B, S_max))
        bias = causal_mask_bias(positions, k_pos, window)
        out = softmax_attend(q, ck.astype(dt), cv.astype(dt), bias)
        new_cache = {"k": ck, "v": cv}

    out = shard(out, "batch", "seq", "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return shard(out, "batch", "seq", None), new_cache


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype) -> dict:
    return {"k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           dtype),
            "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim),
                           dtype)}


# --------------------------------------------------------- cross-attention
def init_cross(key, cfg: ModelConfig) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], d, H * Dh).reshape(d, H, Dh),
            "wk": dense_init(ks[1], d, Hkv * Dh).reshape(d, Hkv, Dh),
            "wv": dense_init(ks[2], d, Hkv * Dh).reshape(d, Hkv, Dh),
            "wo": dense_init(ks[3], H * Dh, d).reshape(H, Dh, d)}


def cross_apply(params: dict, x: jnp.ndarray, enc_out: jnp.ndarray
                ) -> jnp.ndarray:
    """Decoder cross-attention over encoder output (no mask)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dt))
    bias = jnp.zeros((x.shape[0], x.shape[1], enc_out.shape[1]), jnp.float32)
    out = softmax_attend(q, k, v, bias)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
