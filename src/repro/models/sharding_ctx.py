"""Logical-axis sharding context (MaxText-style, minimal).

Model code annotates activations with *logical* axis names via
``shard(x, "batch", "seq", None)``.  Outside any context this is the
identity, so the model runs on a single CPU device unchanged.  The
launch layer activates a mesh + rules mapping logical names to mesh
axes; ``shard`` then applies ``with_sharding_constraint`` so GSPMD
propagates the intended layout.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

AxisVal = Union[None, str, Tuple[str, ...]]


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules() -> Dict[str, AxisVal]:
    return getattr(_state, "rules", {})


@contextlib.contextmanager
def logical_axis_rules(mesh: Mesh, rules: Dict[str, AxisVal]):
    """Activate (mesh, logical->physical rules) for model tracing."""
    old_mesh = getattr(_state, "mesh", None)
    old_rules = getattr(_state, "rules", {})
    _state.mesh, _state.rules = mesh, dict(rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = old_mesh, old_rules


def logical_to_spec(axes: Sequence[Optional[str]]) -> P:
    rules = current_rules()
    return P(*[rules.get(a) if a is not None else None for a in axes])


def _manual_axes() -> frozenset:
    """Mesh axes that are Manual in the current trace context (inside a
    shard_map region) — constraints must not mention them."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.axis_names:
            return frozenset()
        return frozenset(
            n for n, t in zip(am.axis_names, am.axis_types)
            if t == jax.sharding.AxisType.Manual)
    except Exception:
        return frozenset()


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the sharding implied by logical ``axes``.
    Axes that are currently manual (we are inside a shard_map over
    them) are dropped from the constraint — the value is already
    device-local along those."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard: {len(axes)} axes for rank-{x.ndim} array")
    spec = logical_to_spec(axes)
    manual = _manual_axes()
    if manual:
        entries = []
        for e in spec:
            if e is None:
                entries.append(None)
            elif isinstance(e, str):
                entries.append(None if e in manual else e)
            else:
                kept = tuple(a for a in e if a not in manual)
                entries.append(kept if kept else None)
        spec = P(*entries)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
