"""Logical-axis sharding context (MaxText-style, minimal).

Model code annotates activations with *logical* axis names via
``shard(x, "batch", "seq", None)``.  Outside any context this is the
identity, so the model runs on a single CPU device unchanged.  The
launch layer activates a mesh + rules mapping logical names to mesh
axes; ``shard`` then applies ``with_sharding_constraint`` so GSPMD
propagates the intended layout.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

AxisVal = Union[None, str, Tuple[str, ...]]


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules() -> Dict[str, AxisVal]:
    return getattr(_state, "rules", {})


@contextlib.contextmanager
def logical_axis_rules(mesh: Mesh, rules: Dict[str, AxisVal]):
    """Activate (mesh, logical->physical rules) for model tracing."""
    old_mesh = getattr(_state, "mesh", None)
    old_rules = getattr(_state, "rules", {})
    _state.mesh, _state.rules = mesh, dict(rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = old_mesh, old_rules


def logical_to_spec(axes: Sequence[Optional[str]]) -> P:
    rules = current_rules()
    return P(*[rules.get(a) if a is not None else None for a in axes])


def _manual_axes() -> frozenset:
    """Mesh axes that are Manual in the current trace context (inside a
    shard_map region) — constraints must not mention them."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.axis_names:
            return frozenset()
        return frozenset(
            n for n, t in zip(am.axis_names, am.axis_types)
            if t == jax.sharding.AxisType.Manual)
    except Exception:
        pass
    # jax 0.4.x has no abstract-mesh query; axis names bound by an
    # enclosing shard_map/pmap live in the trace axis env instead
    # (vmap's spmd_axis_name deliberately does NOT appear — those
    # constraints are extended by the vmap machinery itself).
    try:
        names = jax.core.unsafe_get_axis_names_DO_NOT_USE()
        return frozenset(n for n in names if isinstance(n, str))
    except Exception:
        return frozenset()


def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """Version-portable ``shard_map`` front-end.

    Newer jax exposes ``jax.shard_map(..., axis_names=, check_vma=)``
    where ``axis_names`` lists the MANUAL mesh axes (the rest stay
    GSPMD-auto).  jax 0.4.x instead has
    ``jax.experimental.shard_map.shard_map(..., auto=, check_rep=)``
    where ``auto`` lists the NON-manual axes.  Both the repro.dist
    runtime and tests/dist_checks.py go through this wrapper so the
    same source runs on either API.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=bool(check_vma),
                      auto=auto)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the sharding implied by logical ``axes``.
    Axes that are currently manual (we are inside a shard_map over
    them) are dropped from the constraint — the value is already
    device-local along those."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard: {len(axes)} axes for rank-{x.ndim} array")
    spec = logical_to_spec(axes)
    manual = _manual_axes()
    if manual:
        entries = []
        for e in spec:
            if e is None:
                entries.append(None)
            elif isinstance(e, str):
                entries.append(None if e in manual else e)
            else:
                kept = tuple(a for a in e if a not in manual)
                entries.append(kept if kept else None)
        spec = P(*entries)
    if all(e is None for e in spec):
        # nothing left to constrain (e.g. fully-manual shard_map body)
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
