"""RWKV6 ("Finch") block — data-dependent per-channel decay linear
attention (attention-free), time-mix + channel-mix.

Recurrence per head (K = V = head dim):
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t,     w_t = exp(-exp(w0 + lora(x_t)))
Training uses a chunked formulation: within a chunk the pairwise decay
products are materialized as a [cl, cl, K] tensor (exact, no division
by vanishing decay products — numerically safe for any w), chunks are
scanned with the [B,H,K,V] state carried; the scanned body is
rematerialized.  Decode is the raw recurrence step.

Simplifications vs the released RWKV6 (noted for the record): static
token-shift lerp (no data-dependent lerp LoRA), per-head RMS instead of
GroupNorm on the WKV output.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init
from .sharding_ctx import shard

_W_LORA = 64


def init_rwkv_time(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, K = cfg.rwkv_heads, cfg.ssm_head_dim
    ks = jax.random.split(key, 9)
    return {
        "mu": jnp.full((5, d), 0.5, jnp.float32),   # r,k,v,g,w shift lerps
        "wr": dense_init(ks[0], d, d),
        "wk": dense_init(ks[1], d, d),
        "wv": dense_init(ks[2], d, d),
        "wg": dense_init(ks[3], d, d),
        "w0": jnp.full((d,), -0.6, jnp.float32),    # decay bias
        "w_a": dense_init(ks[4], d, _W_LORA),
        "w_b": dense_init(ks[5], _W_LORA, d, scale=0.1),
        "u": (jax.random.normal(ks[6], (H, K), jnp.float32) * 0.1),
        "ln_w": jnp.ones((H, K), jnp.float32),      # per-head output norm
        "wo": dense_init(ks[7], d, d),
    }


def init_rwkv_channel(key, cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"mu": jnp.full((2, d), 0.5, jnp.float32),
            "wk": dense_init(ks[0], d, ff),
            "wv": dense_init(ks[1], ff, d),
            "wr": dense_init(ks[2], d, d)}


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray]
                 ) -> jnp.ndarray:
    """x_{t-1} stream; last: [B,d] previous token (decode) or None."""
    if x.shape[1] == 1 and last is not None:
        return last[:, None, :].astype(x.dtype)
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if last is not None:
        prev = prev.at[:, 0].set(last.astype(x.dtype))
    return prev


def rwkv_time_apply(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                    state: Optional[dict] = None
                    ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: [B,S,d] -> (y, new_state); state = {"S": [B,H,K,K] f32,
    "last": [B,d]}."""
    dt_ = x.dtype
    B, S, d = x.shape
    H, K = cfg.rwkv_heads, cfg.ssm_head_dim
    prev = _token_shift(x, state["last"] if state else None)
    mu = params["mu"].astype(dt_)
    xr, xk, xv, xg, xw = (x + mu[i] * (prev - x) for i in range(5))

    r = (xr @ params["wr"].astype(dt_)).reshape(B, S, H, K)
    k = (xk @ params["wk"].astype(dt_)).reshape(B, S, H, K)
    v = (xv @ params["wv"].astype(dt_)).reshape(B, S, H, K)
    g = jax.nn.silu(xg @ params["wg"].astype(dt_))
    # data-dependent decay (RWKV6's signature feature)
    w_raw = params["w0"].astype(jnp.float32) + (
        jnp.tanh(xw.astype(jnp.float32) @ params["w_a"])
        @ params["w_b"])                                  # [B,S,d]
    logw = -jnp.exp(w_raw).reshape(B, S, H, K)            # log w_t < 0
    u = params["u"]

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    if S == 1 and state is not None:
        S0 = state["S"]
        kv = jnp.einsum("bhk,bhv->bhkv", kf[:, 0], vf[:, 0])
        wkv = S0 + u[None, :, :, None] * kv
        y = jnp.einsum("bhk,bhkv->bhv", rf[:, 0], wkv)[:, None]
        S_new = jnp.exp(logw[:, 0])[..., None] * S0 + kv
        new_state = {"S": S_new, "last": x[:, -1].astype(jnp.float32)}
        y = y.reshape(B, 1, H, K)
    else:
        y, S_last = _wkv_chunked(rf, kf, vf, logw, u,
                                 state["S"] if state else None, cfg)
        new_state = None if state is None else {
            "S": S_last, "last": x[:, -1].astype(jnp.float32)}

    # per-head normalization + gating
    yn = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-6)
    yn = yn * params["ln_w"][None, None]
    out = (yn.reshape(B, S, d).astype(dt_) * g) @ params["wo"].astype(dt_)
    return shard(out, "batch", "seq", None), new_state


def _wkv_chunked(r, k, v, logw, u, S0, cfg: ModelConfig):
    """r/k/v/logw: [B,S,H,K] f32.  Returns (y [B,S,H,K], S_last)."""
    B, S, H, K = r.shape
    cl = min(32, S)
    assert S % cl == 0, f"seq {S} not divisible by rwkv chunk {cl}"
    nc = S // cl

    def rc(t):
        return t.reshape(B, nc, cl, H, K).transpose(1, 0, 2, 3, 4)

    rch, kch, vch, lwch = rc(r), rc(k), rc(v), rc(logw)

    def body(S_prev, inp):
        rb, kb, vb, lwb = inp                     # [B,cl,H,K]
        cum = jnp.cumsum(lwb, axis=1)             # inclusive
        cum_prev = cum - lwb                      # exclusive
        # state contribution
        r_dec = rb * jnp.exp(cum_prev)
        y_state = jnp.einsum("bthk,bhkv->bthv", r_dec, S_prev)
        # intra-chunk pairwise (exact 3-tensor decay, s < t)
        ldiff = cum_prev[:, :, None] - cum[:, None, :, :]  # [B,t,s,H,K]
        mask = (jnp.arange(cl)[:, None] > jnp.arange(cl)[None, :])
        # mask inside exp (inf * 0 = NaN in the VJP otherwise)
        e = jnp.exp(jnp.where(mask[None, :, :, None, None], ldiff,
                              -jnp.inf))
        A = jnp.einsum("bthk,bshk,btshk->btsh", rb, kb, e)
        y_intra = jnp.einsum("btsh,bshv->bthv", A, vb)
        # diagonal bonus term
        y_diag = jnp.einsum("bthk,hk,bthk,bthv->bthv", rb, u, kb, vb)
        # state update
        dec_tail = jnp.exp(cum[:, -1:, :] - cum)  # [B,cl,H,K]
        S_new = (jnp.exp(cum[:, -1])[..., None] * S_prev
                 + jnp.einsum("bshk,bshv->bhkv", kb * dec_tail, vb))
        return S_new, y_state + y_intra + y_diag

    if S0 is None:
        S0 = jnp.zeros((B, H, K, K), jnp.float32)
    S_last, ys = jax.lax.scan(jax.checkpoint(body), S0,
                              (rch, kch, vch, lwch))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, K)
    return y, S_last


def rwkv_time_naive(r, k, v, logw, u, S0=None):
    """Step-by-step oracle for tests.  r/k/v/logw: [B,S,H,K] f32."""
    B, S, H, K = r.shape
    if S0 is None:
        S0 = jnp.zeros((B, H, K, K), jnp.float32)

    def step(Sp, t):
        kv = jnp.einsum("bhk,bhv->bhkv", k[:, t], v[:, t])
        y = jnp.einsum("bhk,bhkv->bhv", r[:, t],
                       Sp + u[None, :, :, None] * kv)
        S_new = jnp.exp(logw[:, t])[..., None] * Sp + kv
        return S_new, y

    S_last, ys = jax.lax.scan(step, S0, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3), S_last


def rwkv_channel_apply(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                       state: Optional[dict] = None
                       ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """RWKV channel-mix FFN.  state = {"last": [B,d]}."""
    dt_ = x.dtype
    prev = _token_shift(x, state["last"] if state else None)
    mu = params["mu"].astype(dt_)
    xk = x + mu[0] * (prev - x)
    xr = x + mu[1] * (prev - x)
    kk = jnp.square(jax.nn.relu(xk @ params["wk"].astype(dt_)))
    kk = shard(kk, "batch", "seq", "ffn")
    kv = kk @ params["wv"].astype(dt_)
    out = jax.nn.sigmoid(xr @ params["wr"].astype(dt_)) * kv
    new_state = None if state is None else {
        "last": x[:, -1].astype(jnp.float32)}
    return shard(out, "batch", "seq", None), new_state


def init_rwkv_state(cfg: ModelConfig, batch: int) -> dict:
    H, K, d = cfg.rwkv_heads, cfg.ssm_head_dim, cfg.d_model
    return {"time": {"S": jnp.zeros((batch, H, K, K), jnp.float32),
                     "last": jnp.zeros((batch, d), jnp.float32)},
            "channel": {"last": jnp.zeros((batch, d), jnp.float32)}}
