"""Mixture-of-Experts FFN with expert-parallel all-to-all dispatch.

Routing (top-k, softmax-normalized over the selected experts, GShard-
style capacity with drop) is computed in GSPMD-land; the token dispatch
+ expert FFN + combine run inside ``shard_map`` so the expert-parallel
``all_to_all`` over the model axis is explicit — this is the collective
the roofline must see for MoE architectures.

Two dispatch paths:
* **a2a** — batch sharded over data axes: sort-based local dispatch
  into per-expert capacity buffers, ``all_to_all`` over the expert
  (model) axis, per-expert SwiGLU, ``all_to_all`` back, weighted
  combine.
* **replicated** — no mesh / batch-1 decode: every device computes its
  local experts' outputs and a ``psum`` over the expert axis combines
  (no mesh at all -> plain local computation, used as the oracle).

Experts are padded to ``num_experts_padded`` for mesh divisibility;
padding experts get -inf router logits and are never selected.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import dense_init, init_mlp, mlp_apply
from .sharding_ctx import (_manual_axes, current_mesh, current_rules,
                           shard, shard_map)


def _inner_mesh(mesh):
    """Mesh argument for a shard_map that may be nested inside a
    partial-manual region: the context's AbstractMesh when one is
    active (required for nesting), else the concrete mesh."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            return None            # infer from context
    except Exception:
        pass
    return mesh


def init_moe(key, cfg: ModelConfig) -> dict:
    d, E, ff = cfg.d_model, cfg.num_experts_padded, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, E),
        "w_gate": jax.vmap(lambda k: dense_init(k, d, ff))(
            jax.random.split(ks[1], E)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, ff))(
            jax.random.split(ks[2], E)),
        "w_down": jax.vmap(lambda k: dense_init(k, ff, d))(
            jax.random.split(ks[3], E)),
    }
    if cfg.num_shared_experts:
        shared_ff = cfg.moe_d_ff * cfg.num_shared_experts
        p["shared"] = init_mlp(ks[4], d, shared_ff)
    return p


def _route(params: dict, x: jnp.ndarray, cfg: ModelConfig
           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Router: top-k indices, normalized weights, aux load-balance loss."""
    dt = x.dtype
    E, Ep, k = cfg.num_experts, cfg.num_experts_padded, cfg.top_k
    logits = (x @ params["router"].astype(dt)).astype(jnp.float32)
    if Ep > E:
        pad_mask = jnp.arange(Ep) >= E
        logits = jnp.where(pad_mask, -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)                 # [B,S,Ep]
    top_w, top_idx = jax.lax.top_k(probs, k)                # [B,S,k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # Switch-style aux loss: E * sum_e f_e * p_e
    f = jnp.mean(jax.nn.one_hot(top_idx, Ep, dtype=jnp.float32),
                 axis=(0, 1, 2))
    p_mean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f * p_mean) * k
    return top_idx, top_w.astype(dt), aux


def _local_dispatch(x_flat, top_idx, top_w, Ep: int, C: int):
    """Sort-based capacity dispatch of local tokens.

    Returns (buffer [Ep, C, d], combine info) with static shapes; tokens
    beyond capacity are dropped (contribute zero, weight renormalized is
    NOT applied — standard GShard drop semantics)."""
    T, d = x_flat.shape
    k = top_idx.shape[-1]
    e_flat = top_idx.reshape(-1)                    # [T*k]
    w_flat = top_w.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(e_flat)
    e_s, w_s, tok_s = e_flat[order], w_flat[order], tok_flat[order]
    counts = jnp.zeros((Ep,), jnp.int32).at[e_s].add(1)
    starts = jnp.cumsum(counts) - counts            # exclusive cumsum
    pos = jnp.arange(T * k) - starts[e_s]           # rank within expert
    keep = pos < C
    pos_sc = jnp.where(keep, pos, C)                # OOB -> dropped
    buf = jnp.zeros((Ep, C, d), x_flat.dtype)
    buf = buf.at[e_s, pos_sc].set(x_flat[tok_s], mode="drop")
    return buf, (e_s, pos_sc, tok_s, w_s)


def _local_combine(y_buf, info, T: int, d: int):
    e_s, pos_sc, tok_s, w_s = info
    gathered = y_buf.at[e_s, pos_sc].get(mode="fill", fill_value=0.0)
    out = jnp.zeros((T, d), y_buf.dtype)
    return out.at[tok_s].add(gathered * w_s[:, None])


def _expert_ffn(w_gate, w_up, w_down, xe, dtype):
    """xe: [E_local, C', d] -> per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w_up.astype(dtype))
    return jnp.einsum("ecf,efd->ecd", h, w_down.astype(dtype))


def moe_apply(params: dict, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y, aux_loss)."""
    dt = x.dtype
    B, S, d = x.shape
    Ep, k = cfg.num_experts_padded, cfg.top_k
    top_idx, top_w, aux = _route(params, x, cfg)

    mesh = current_mesh()
    rules = current_rules()
    expert_axis = rules.get("expert") if mesh is not None else None

    if expert_axis is None:
        # oracle / single-device path: all experts local
        x_flat = x.reshape(B * S, d)
        C = max(4, math.ceil(B * S * k / Ep * cfg.capacity_factor))
        buf, info = _local_dispatch(x_flat, top_idx.reshape(B * S, k),
                                    top_w.reshape(B * S, k), Ep, C)
        y_buf = _expert_ffn(params["w_gate"], params["w_up"],
                            params["w_down"], buf, dt)
        y = _local_combine(y_buf, info, B * S, d).reshape(B, S, d)
    else:
        y = _moe_shard_map(params, x, top_idx, top_w, cfg, mesh, rules)

    if cfg.num_shared_experts:
        y = y + _shared_expert(params["shared"], x, dt, mesh, rules)
    return shard(y, "batch", "seq", None), aux


def _shared_expert(sp: dict, x: jnp.ndarray, dt, mesh, rules) -> jnp.ndarray:
    """Always-active shared expert path — plain SwiGLU; GSPMD shards the
    hidden dim over the model axis via the ffn logical axis."""
    h = jax.nn.silu(x @ sp["w_gate"].astype(dt)) * (x @ sp["w_up"].astype(dt))
    h = shard(h, "batch", "seq", "ffn")
    return h @ sp["w_down"].astype(dt)


def _moe_shard_map(params, x, top_idx, top_w, cfg: ModelConfig, mesh, rules):
    """Expert-parallel dispatch with explicit all_to_all."""
    dt = x.dtype
    B, S, d = x.shape
    Ep, k = cfg.num_experts_padded, cfg.top_k
    expert_axis = rules["expert"]                  # e.g. "model"
    batch_axes = rules.get("batch")                # e.g. ("pod","data")
    ea_size = mesh.shape[expert_axis]
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    # axes already manual (we are nested inside a shard_map over them,
    # e.g. the per-replica training region): x is already local there.
    manual = _manual_axes()
    batch_axes = tuple(a for a in (batch_axes or ())
                       if a not in manual) or None
    bs_size = 1
    if batch_axes:
        for a in batch_axes:
            bs_size *= mesh.shape[a]

    seq_shardable = (S % ea_size == 0) and S > 1
    replicated_batch = (not batch_axes or (B % bs_size != 0)) \
        and not seq_shardable
    if replicated_batch:
        # batch-1 decode: tokens replicated; each device computes its
        # local experts and a psum over the expert axis combines.
        def repl_fn(wg, wu, wd, xl, ti, tw):
            E_loc = wg.shape[0]
            ax_idx = jax.lax.axis_index(expert_axis)
            e_off = ax_idx * E_loc
            T = xl.shape[0] * xl.shape[1]
            x_flat = xl.reshape(T, d)
            til = ti.reshape(T, k) - e_off         # local expert ids
            twl = tw.reshape(T, k)
            valid = (til >= 0) & (til < E_loc)
            twl = jnp.where(valid, twl, 0.0)
            til = jnp.clip(til, 0, E_loc - 1)
            C = max(4, math.ceil(T * k / Ep * cfg.capacity_factor) * 4)
            buf, info = _local_dispatch(x_flat, til, twl, E_loc, C)
            y_buf = _expert_ffn(wg, wu, wd, buf, dt)
            y = _local_combine(y_buf, info, T, d)
            y = jax.lax.psum(y, expert_axis)
            return y.reshape(xl.shape)

        return shard_map(
            repl_fn, mesh=_inner_mesh(mesh),
            in_specs=(P(expert_axis), P(expert_axis), P(expert_axis),
                      P(), P(), P()),
            out_specs=P(),
            check_vma=False,
        )(params["w_gate"].astype(dt), params["w_up"].astype(dt),
          params["w_down"].astype(dt), x, top_idx, top_w)

    # ---- a2a path: batch sharded over data axes ----
    # x is replicated along the expert (model) axis, so we additionally
    # shard the SEQUENCE dim over it inside the shard_map (free local
    # slice on entry; GSPMD all-gathers the output back) — otherwise
    # every model-peer would dispatch identical tokens and the experts
    # would compute W redundant copies.  Falls back to the redundant
    # layout when S is not divisible (S == 1 decode: negligible waste).
    seq_sharded = (S % ea_size == 0) and S > 1
    S_l = S // ea_size if seq_sharded else S
    T_l = (B // bs_size) * S_l
    C_l = max(4, math.ceil(T_l * k / Ep * cfg.capacity_factor))

    def a2a_fn(wg, wu, wd, xl, ti, tw):
        Bl = xl.shape[0]
        x_flat = xl.reshape(Bl * S_l, d)
        buf, info = _local_dispatch(x_flat, ti.reshape(-1, k),
                                    tw.reshape(-1, k), Ep, C_l)
        # [Ep, C_l, d] -> [Ep/W, W*C_l, d]: tokens for my local experts
        xe = jax.lax.all_to_all(buf, expert_axis, split_axis=0,
                                concat_axis=1, tiled=True)
        ye = _expert_ffn(wg, wu, wd, xe, dt)
        y_buf = jax.lax.all_to_all(ye, expert_axis, split_axis=1,
                                   concat_axis=0, tiled=True)
        y = _local_combine(y_buf, info, Bl * S_l, d)
        return y.reshape(Bl, S_l, d)

    if batch_axes:
        batuple = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    else:
        batuple = None
    seq_ax = expert_axis if seq_sharded else None
    bspec = P(batuple, seq_ax)
    return shard_map(
        a2a_fn, mesh=_inner_mesh(mesh),
        in_specs=(P(expert_axis), P(expert_axis), P(expert_axis),
                  bspec, bspec, bspec),
        out_specs=bspec,
        check_vma=False,
    )(params["w_gate"].astype(dt), params["w_up"].astype(dt),
      params["w_down"].astype(dt), x, top_idx, top_w)
