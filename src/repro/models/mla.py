"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Queries go through a low-rank bottleneck (q_lora_rank); keys/values are
compressed into a kv_lora_rank latent plus a single shared RoPE key.
The decode cache stores only the latent + rope key (the MLA memory
win): per token ``kv_lora_rank + qk_rope_head_dim`` instead of
``2 * H * head_dim``.  The baseline decode path re-expands K/V from the
latent each step; weight absorption is a §Perf iteration.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (apply_rope, causal_mask_bias, chunked_softmax_attend,
                     dense_init, rms_norm)
from .sharding_ctx import shard


def init_mla(key, cfg: ModelConfig) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], d, qr),
        "q_norm": jnp.ones((qr,), jnp.float32),
        "w_uq": dense_init(ks[1], qr, H * (dn + dr)).reshape(qr, H, dn + dr),
        "w_dkv": dense_init(ks[2], d, kvr),
        "kv_norm": jnp.ones((kvr,), jnp.float32),
        "w_uk": dense_init(ks[3], kvr, H * dn).reshape(kvr, H, dn),
        "w_uv": dense_init(ks[4], kvr, H * dv).reshape(kvr, H, dv),
        "w_kr": dense_init(ks[5], d, dr),
        "wo": dense_init(ks[6], H * dv, d).reshape(H, dv, d),
    }


def _expand_kv(params: dict, latent: jnp.ndarray, k_rope: jnp.ndarray,
               cfg: ModelConfig, dt) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """latent: [B,S,kvr] (already normed), k_rope: [B,S,dr] (roped)."""
    k_nope = jnp.einsum("bsr,rhk->bshk", latent, params["w_uk"].astype(dt))
    v = jnp.einsum("bsr,rhk->bshk", latent, params["w_uv"].astype(dt))
    kr = jnp.broadcast_to(k_rope[:, :, None, :],
                          k_nope.shape[:3] + (cfg.qk_rope_head_dim,))
    k = jnp.concatenate([k_nope, kr], axis=-1)
    return k, v


def mla_apply(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
              cfg: ModelConfig, window: int = 0,
              cache: Optional[dict] = None,
              cache_index: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, Optional[dict]]:
    dt = x.dtype
    B, S, _ = x.shape
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim

    # queries through the low-rank bottleneck
    q_lat = rms_norm(x @ params["w_dq"].astype(dt), params["q_norm"],
                     cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["w_uq"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    if cache is None:
        # §Perf iteration P2/P3: MLA head counts (40) do not divide the
        # model axis (16), so head/head_dim TP turns every score einsum
        # into an all-reduce of [B,H,Sq,Sk] partials (~2.5 TB/step
        # measured).  Instead: queries SEQUENCE-sharded over the model
        # axis (context-parallel), keys/values head-gathered per device
        # (they come from a small latent — ~0.5 GB vs TBs).
        q = shard(q, "batch", "res_seq", None, None)
    else:
        q = shard(q, "batch", "seq", "heads", None)

    # compressed kv latent + shared rope key
    latent = rms_norm(x @ params["w_dkv"].astype(dt), params["kv_norm"],
                      cfg.norm_eps)
    k_rope = apply_rope((x @ params["w_kr"].astype(dt))[:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0, :]

    if cache is None:
        k, v = _expand_kv(params, latent, k_rope, cfg, dt)
        k = shard(k, "batch", None, None, None)   # heads replicated
        v = shard(v, "batch", None, None, None)
        if S > 2048:
            out = chunked_softmax_attend(q, k, v, positions, positions,
                                         window=window)
        else:
            bias = causal_mask_bias(positions, positions, window)
            out = _attend(q, k, v, bias)
        new_cache = None
    else:
        idx = cache_index
        clat = jax.lax.dynamic_update_slice_in_dim(
            cache["latent"], latent.astype(cache["latent"].dtype), idx, 1)
        ckr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), idx, 1)
        k, v = _expand_kv(params, clat.astype(dt), ckr.astype(dt), cfg, dt)
        S_max = clat.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(S_max)[None, :], (B, S_max))
        bias = causal_mask_bias(positions, k_pos, window)
        out = _attend(q, k, v, bias)
        new_cache = {"latent": clat, "k_rope": ckr}

    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return shard(out, "batch", "seq", None), new_cache


def _attend(q, k, v, bias):
    """MHA (no GQA grouping) with distinct qk/v head dims."""
    D = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(D)
    scores = scores + bias[:, None, :, :]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    return {"latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim),
                                dtype)}
