"""Shared building blocks: norms, RoPE, MLPs, init helpers.

Conventions:
* params are nested dicts of jnp arrays, stored float32;
* forward functions cast to the config compute dtype at use;
* all linears are bias-free (Llama-style) for uniformity across the
  zoo — a documented simplification for Whisper, which has biases.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, scale: float = 1.0) -> jnp.ndarray:
    """Truncated-normal fan-in init."""
    std = scale / jnp.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out),
                                        jnp.float32) * std)


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray,
             eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                      # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [...,S,hd/2]
    angles = angles[..., None, :]                            # [...,S,1,hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def init_mlp(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d_model, d_ff),
            "w_up": dense_init(k2, d_model, d_ff),
            "w_down": dense_init(k3, d_ff, d_model)}


def mlp_apply(params: dict, x: jnp.ndarray, dtype) -> jnp.ndarray:
    return swiglu(x,
                  params["w_gate"].astype(dtype),
                  params["w_up"].astype(dtype),
                  params["w_down"].astype(dtype))


def causal_mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                     window: int = 0) -> jnp.ndarray:
    """Additive attention bias: 0 where k may attend, -inf otherwise.

    q_pos: [..., Sq], k_pos: [..., Sk] absolute positions.
    window > 0 enables sliding-window attention (k in
    (q - window, q]).
    """
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def expand_kv(k: jnp.ndarray, H: int) -> jnp.ndarray:
    """GQA kv-head expansion [B,S,Hkv,D] -> [B,S,H,D].

    When the tensor-parallel degree exceeds the kv head count, the
    grouped [B,S,Hkv,g,D] layout cannot carry a clean 16-way sharding
    (the head dim splits as Hkv x g and GSPMD falls back to partial
    replication).  Expanding kv to the full head count keeps every
    attention tensor sharded H-ways — the standard TP treatment; the
    expanded copy is itself sharded so the memory cost is Hkv/H-small.
    """
    Hkv = k.shape[2]
    if Hkv == H:
        return k
    return jnp.repeat(k, H // Hkv, axis=2)


def softmax_attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   bias: jnp.ndarray) -> jnp.ndarray:
    """q: [B,Sq,H,D], k/v: [B,Sk,Hkv,D(v)], bias: [B?,Sq,Sk] additive.

    GQA: kv heads are expanded to H (see expand_kv).  Plain
    (non-chunked) attention — short sequences and the oracle for the
    chunked/online-softmax path."""
    B, Sq, H, D = q.shape
    kf = expand_kv(k, H).astype(jnp.float32)
    vf = expand_kv(v, H).astype(jnp.float32)
    qf = q.astype(jnp.float32) / jnp.sqrt(D)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    scores = scores + bias[:, None, :, :]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vf)
    return out.astype(q.dtype)


def chunked_softmax_attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                           window: int = 0,
                           kv_chunk: int = 1024) -> jnp.ndarray:
    """Online-softmax attention, scanning KV chunks (flash-style in XLA).

    Peak memory O(Sq * kv_chunk) instead of O(Sq * Sk).  The scanned
    body is rematerialized (jax.checkpoint) so the backward pass does
    not store per-chunk score tensors.  kv heads are expanded to H
    (expand_kv) so every tensor carries the full H-way model sharding.
    """
    B, Sq, H, D = q.shape
    k = expand_kv(k, H)
    v = expand_kv(v, H)
    Sk = k.shape[1]
    Dv = v.shape[-1]
    n_chunks = -(-Sk // kv_chunk)
    pad = n_chunks * kv_chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2 ** 30)
    kc = k.reshape(B, n_chunks, kv_chunk, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, H, Dv).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(B, n_chunks, kv_chunk).transpose(1, 0, 2)

    qf = q.astype(jnp.float32) / jnp.sqrt(D)

    def body(carry, chunk):
        m, l, acc = carry
        kch, vch, pch = chunk
        scores = jnp.einsum("bqhd,bkhd->bhqk", qf,
                            kch.astype(jnp.float32))
        bias = causal_mask_bias(q_pos, pch, window)          # [B,Sq,Ck]
        scores = scores + bias[:, None, :, :]
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        # guard fully-masked rows (all -inf) -> m_new may be -inf
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(scores - m_safe[..., None])
        p = jnp.where(jnp.isfinite(scores), p, 0.0)
        scale = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vch.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, acc0),
                                  (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3)
    return out.astype(q.dtype)
