"""Mamba2 (SSD) block — chunked, matmul-dominant TPU formulation.

State-space dual form with per-head scalar decay:
    h_t = exp(dt_t * a_h) h_{t-1} + dt_t * x_t (x) B_t,   y_t = C_t h_t + D x
Training uses the chunked SSD algorithm (intra-chunk quadratic matmuls
+ inter-chunk state scan), which maps the recurrence onto the MXU —
this is the TPU adaptation of Mamba2's GPU kernel.  Decode is the raw
single-step recurrence.  Single B/C group shared across heads
(n_groups=1), depthwise causal conv over (x, B, C) as in Mamba2.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, rms_norm
from .sharding_ctx import shard


def init_mamba(key, cfg: ModelConfig) -> dict:
    """Projections are stored separately (wz/wx/wB/wC/wdt) rather than
    as one fused in_proj so each output dim can be sharded cleanly
    (d_inner and H divide the model axis; the small B/C state
    projections stay replicated)."""
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim
    H = cfg.ssm_heads
    ks = jax.random.split(key, 8)
    return {
        "wz": dense_init(ks[0], d, di),
        "wx": dense_init(ks[1], d, di),
        "wB": dense_init(ks[2], d, N),
        "wC": dense_init(ks[3], d, N),
        "wdt": dense_init(ks[4], d, H),
        # depthwise conv split: x channels (model-sharded) and B/C
        # channels (replicated) — a fused conv over the concat would
        # force GSPMD to de-shard the whole inner stream (§Perf P4)
        "conv_wx": (jax.random.normal(ks[5], (cfg.conv_width, di),
                                      jnp.float32) * 0.1),
        "conv_wbc": (jax.random.normal(ks[7], (cfg.conv_width, 2 * N),
                                       jnp.float32) * 0.1),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[6], di, d),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 conv_state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv, width W.  x: [B,S,C]; w: [W,C].
    conv_state: [B, W-1, C] tail from previous tokens (decode)."""
    W = w.shape[0]
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
              for i in range(W))
    new_state = xp[:, -(W - 1):, :]
    return jax.nn.silu(out), new_state


def mamba_apply(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[dict] = None
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: [B,S,d] -> (y, new_state).  state = {"h": [B,H,P,N],
    "conv": [B,W-1,C]} for decode (S == 1)."""
    dt_ = x.dtype
    B, S, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_heads, \
        cfg.ssm_head_dim

    z = x @ params["wz"].astype(dt_)
    xc = shard(x @ params["wx"].astype(dt_), "batch", "seq", "ssm_inner")
    Bc = x @ params["wB"].astype(dt_)
    Cc = x @ params["wC"].astype(dt_)
    dt_raw = shard(x @ params["wdt"].astype(dt_), "batch", "seq",
                   "ssm_heads")
    bc_in = jnp.concatenate([Bc, Cc], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_state_x = conv_state["x"] if conv_state is not None else None
    conv_state_bc = conv_state["bc"] if conv_state is not None else None
    xc, new_conv_x = _causal_conv(xc, params["conv_wx"], conv_state_x)
    xc = shard(xc, "batch", "seq", "ssm_inner")
    bc_out, new_conv_bc = _causal_conv(bc_in, params["conv_wbc"],
                                       conv_state_bc)
    Bc, Cc = jnp.split(bc_out, [N], axis=-1)
    new_conv = {"x": new_conv_x, "bc": new_conv_bc}

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])            # [B,S,H]
    a = -jnp.exp(params["A_log"])                        # [H], negative
    log_dec = dt * a                                     # [B,S,H] <= 0
    xh = xc.reshape(B, S, H, P).astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)

    if S == 1 and state is not None:
        h = state["h"]                                   # [B,H,P,N] f32
        decay = jnp.exp(log_dec[:, 0])                   # [B,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xh[:, 0], Bf[:, 0])
        h_new = h * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h_new, Cf[:, 0])[:, None]
        y = y.reshape(B, 1, H, P)
        new_state = {"h": h_new, "conv": new_conv}
    else:
        y, h_last = _ssd_chunked(xh, Bf, Cf, dt, log_dec, cfg)
        new_state = None if state is None else {"h": h_last,
                                                "conv": new_conv}

    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, S, di).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    return shard(out, "batch", "seq", None), new_state


def _ssd_chunked(xh, Bf, Cf, dt, log_dec, cfg: ModelConfig):
    """Chunked SSD.  xh: [B,S,H,P] f32, Bf/Cf: [B,S,N], dt/log_dec:
    [B,S,H].  Returns (y [B,S,H,P], h_last [B,H,P,N])."""
    B, S, H, P = xh.shape
    N = Bf.shape[-1]
    cl = min(cfg.ssm_chunk, S)
    assert S % cl == 0, f"seq {S} not divisible by ssm_chunk {cl}"
    nc = S // cl

    def r(t, tail):  # reshape into chunks
        return t.reshape((B, nc, cl) + tail)

    xch, Bch, Cch = r(xh, (H, P)), r(Bf, (N,)), r(Cf, (N,))
    dtc, ldc = r(dt, (H,)), r(log_dec, (H,))
    cum = jnp.cumsum(ldc, axis=2)                        # [B,nc,cl,H]

    def chunk_body(h_prev, inp):
        xcb, Bcb, Ccb, dtb, cumb = inp                   # per-chunk, [B,...]
        # intra-chunk: decay matrix L[t,s] = exp(cum[t]-cum[s]), t >= s
        ldiff = cumb[:, :, None, :] - cumb[:, None, :, :]   # [B,t,s,H]
        tri = jnp.tril(jnp.ones((cl, cl), bool))
        # mask BEFORE exp: exp of the (t < s) positions overflows, and
        # where-after-exp makes the backward pass inf * 0 = NaN
        L = jnp.exp(jnp.where(tri[None, :, :, None], ldiff, -jnp.inf))
        scores = jnp.einsum("btn,bsn->bts", Ccb, Bcb)    # group-shared
        M = scores[..., None] * L                        # [B,t,s,H]
        y_intra = jnp.einsum("btsh,bsh,bshp->bthp", M, dtb, xcb)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bth,btn,bhpn->bthp",
                             jnp.exp(cumb), Ccb, h_prev)
        # chunk state update
        dec_tail = jnp.exp(cumb[:, -1:, :] - cumb)       # [B,cl,H]
        s_c = jnp.einsum("bsh,bsh,bsn,bshp->bhpn",
                         dec_tail, dtb, Bcb, xcb)
        h_new = h_prev * jnp.exp(cumb[:, -1, :])[..., None, None] + s_c
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    inputs = (xch.transpose(1, 0, 2, 3, 4), Bch.transpose(1, 0, 2, 3),
              Cch.transpose(1, 0, 2, 3), dtc.transpose(1, 0, 2, 3),
              cum.transpose(1, 0, 2, 3))
    h_last, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, h_last


def init_mamba_state(cfg: ModelConfig, batch: int) -> dict:
    return {"h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state_dim), jnp.float32),
            "conv": {"x": jnp.zeros((batch, cfg.conv_width - 1,
                                     cfg.d_inner), jnp.float32),
                     "bc": jnp.zeros((batch, cfg.conv_width - 1,
                                      2 * cfg.ssm_state_dim),
                                     jnp.float32)}}
