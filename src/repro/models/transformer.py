"""Model assembly: block dispatch, scan-over-layers, enc-dec, loss.

Layer parameters of each block kind are stacked along a leading axis
and consumed by ``lax.scan`` over contiguous runs of the block pattern
— compile time is O(#runs), not O(depth).  Zamba2's shared attention
block ('S') reuses one parameter set at every 'S' position.

Params tree:
    embed            [V, d]
    frontend         {proj} (vlm/audio stubs)
    encoder          {pos, blocks{A: stacked}, final_norm} (whisper)
    blocks           {kind: stacked-leading-dim params}
    shared           single 'S' block params (zamba2)
    final_norm       [d]
    lm_head          [d, V] (absent when tied)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (cross_apply, gqa_apply, init_cross, init_gqa,
                        init_gqa_cache)
from .config import ModelConfig
from .layers import dense_init, init_mlp, mlp_apply, rms_norm
from .mla import init_mla, init_mla_cache, mla_apply
from .moe import init_moe, moe_apply
from .rwkv import (init_rwkv_channel, init_rwkv_state, init_rwkv_time,
                   rwkv_channel_apply, rwkv_time_apply)
from .sharding_ctx import shard
from .ssm import init_mamba, init_mamba_state, mamba_apply

VISION_FRONTEND_DIM = 1024
AUDIO_FRONTEND_DIM = 128


# ---------------------------------------------------------------- blocks
def init_block(key, cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    if kind in ("A", "S"):
        p = {"norm1": jnp.ones((d,), jnp.float32),
             "norm2": jnp.ones((d,), jnp.float32)}
        if cfg.attn_type == "mla":
            p["attn"] = init_mla(ks[0], cfg)
        else:
            p["attn"] = init_gqa(ks[0], cfg)
        if cfg.num_experts and kind == "A":
            p["moe"] = init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], d, cfg.d_ff)
        if cfg.is_encoder_decoder:
            p["cross_norm"] = jnp.ones((d,), jnp.float32)
            p["cross"] = init_cross(ks[2], cfg)
        return p
    if kind == "M":
        return {"norm": jnp.ones((d,), jnp.float32),
                "mamba": init_mamba(ks[0], cfg)}
    if kind == "R":
        return {"norm1": jnp.ones((d,), jnp.float32),
                "time": init_rwkv_time(ks[0], cfg),
                "norm2": jnp.ones((d,), jnp.float32),
                "channel": init_rwkv_channel(ks[1], cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def block_apply(kind: str, params: dict, x: jnp.ndarray,
                positions: jnp.ndarray, cfg: ModelConfig,
                window: int = 0, cache: Optional[dict] = None,
                cache_index: Optional[jnp.ndarray] = None,
                enc_out: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    x = shard(x, "batch", "res_seq", None)   # sequence-parallel residual
    aux = jnp.zeros((), jnp.float32)
    if kind in ("A", "S"):
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        if cfg.attn_type == "mla":
            a, new_attn_cache = mla_apply(params["attn"], h, positions, cfg,
                                          window, cache and cache["attn"],
                                          cache_index)
        else:
            a, new_attn_cache = gqa_apply(params["attn"], h, positions, cfg,
                                          window, cache and cache["attn"],
                                          cache_index)
        x = x + a
        if cfg.is_encoder_decoder and enc_out is not None:
            h = rms_norm(x, params["cross_norm"], cfg.norm_eps)
            x = x + cross_apply(params["cross"], h, enc_out)
        h = rms_norm(x, params["norm2"], cfg.norm_eps)
        if "moe" in params:
            f, aux = moe_apply(params["moe"], h, cfg)
        else:
            f = mlp_apply(params["mlp"], h, x.dtype)
        x = shard(x + f, "batch", "res_seq", None)
        new_cache = None if cache is None else {"attn": new_attn_cache}
        return x, new_cache, aux
    if kind == "M":
        h = rms_norm(x, params["norm"], cfg.norm_eps)
        m, new_state = mamba_apply(params["mamba"], h, cfg,
                                   cache and cache["mamba"])
        new_cache = None if cache is None else {"mamba": new_state}
        return shard(x + m, "batch", "res_seq", None), new_cache, aux
    if kind == "R":
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        t, new_t = rwkv_time_apply(params["time"], h, cfg,
                                   cache and cache["time"])
        x = x + t
        h = rms_norm(x, params["norm2"], cfg.norm_eps)
        c, new_c = rwkv_channel_apply(params["channel"], h, cfg,
                                      cache and cache["channel"])
        new_cache = None if cache is None else {"time": new_t,
                                                "channel": new_c}
        return shard(x + c, "batch", "res_seq", None), new_cache, aux
    raise ValueError(kind)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype) -> dict:
    if kind in ("A", "S"):
        if cfg.attn_type == "mla":
            return {"attn": init_mla_cache(cfg, batch, max_len, dtype)}
        return {"attn": init_gqa_cache(cfg, batch, max_len, dtype)}
    if kind == "M":
        return {"mamba": init_mamba_state(cfg, batch)}
    if kind == "R":
        return init_rwkv_state(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------- pattern
def pattern_runs(pattern: str):
    """Contiguous runs: [(kind, start_within_kind, length), ...]."""
    runs, counts = [], {}
    i = 0
    while i < len(pattern):
        k = pattern[i]
        j = i
        while j < len(pattern) and pattern[j] == k:
            j += 1
        runs.append((k, counts.get(k, 0), j - i))
        counts[k] = counts.get(k, 0) + (j - i)
        i = j
    return runs


# ---------------------------------------------------------------- model
def init_model(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": dense_init(ks[0], cfg.vocab_padded, d, scale=1.0),
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], d, cfg.vocab_padded)
    counts = cfg.counts()
    blocks = {}
    for kind in "AMR":
        n = counts.get(kind, 0)
        if n:
            keys = jax.random.split(jax.random.fold_in(ks[2], ord(kind)), n)
            blocks[kind] = jax.vmap(
                lambda k, kk=kind: init_block(k, cfg, kk))(keys)
    params["blocks"] = blocks
    if counts.get("S", 0):
        params["shared"] = init_block(ks[3], cfg, "S")
    if cfg.frontend == "vision_stub":
        params["frontend"] = {"proj": dense_init(ks[4], VISION_FRONTEND_DIM,
                                                 d)}
    if cfg.is_encoder_decoder:
        ekeys = jax.random.split(ks[5], cfg.encoder_layers)
        enc_cfg = cfg  # same dims
        params["encoder"] = {
            "proj": dense_init(ks[6], AUDIO_FRONTEND_DIM, d),
            "pos": (jax.random.normal(ks[7], (cfg.encoder_seq, d),
                                      jnp.float32) * 0.02),
            "blocks": jax.vmap(lambda k: _init_enc_block(k, enc_cfg))(ekeys),
            "final_norm": jnp.ones((d,), jnp.float32),
        }
    return params


def _init_enc_block(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_gqa(ks[0], cfg),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff)}


def _enc_block_apply(params, x, positions, cfg):
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    a, _ = _enc_attend(params["attn"], h, positions, cfg)
    x = x + a
    h = rms_norm(x, params["norm2"], cfg.norm_eps)
    return x + mlp_apply(params["mlp"], h, x.dtype)


def _enc_attend(p, h, positions, cfg):
    """Non-causal self-attention (encoder)."""
    from .layers import softmax_attend
    dt = h.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(dt))
    bias = jnp.zeros((h.shape[0], h.shape[1], h.shape[1]), jnp.float32)
    out = softmax_attend(q, k, v, bias)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), None


def encode(params: dict, frames: jnp.ndarray, cfg: ModelConfig
           ) -> jnp.ndarray:
    """Whisper encoder over stub frame embeddings [B, enc_seq, Df]."""
    enc = params["encoder"]
    dt = jnp.dtype(cfg.dtype)
    h = frames.astype(dt) @ enc["proj"].astype(dt)
    h = h + enc["pos"][None].astype(dt)
    positions = jnp.broadcast_to(jnp.arange(h.shape[1])[None],
                                 h.shape[:2])

    def body(carry, p_layer):
        return _enc_block_apply(p_layer, carry, positions, cfg), None

    h, _ = jax.lax.scan(body, h, enc["blocks"])
    return rms_norm(h, enc["final_norm"], cfg.norm_eps)


def embed_inputs(params: dict, batch: Dict[str, jnp.ndarray],
                 cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token (+ modality stub) embedding.  Returns (h [B,S,d],
    loss_mask [B,S])."""
    dt = jnp.dtype(cfg.dtype)
    tok = batch["tokens"]
    h = jnp.take(params["embed"], tok, axis=0).astype(dt)
    mask = jnp.ones(tok.shape, jnp.float32)
    if cfg.frontend == "vision_stub":
        patches = batch["patch_embeds"].astype(dt)
        ph = patches @ params["frontend"]["proj"].astype(dt)
        h = jnp.concatenate([ph, h], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros(ph.shape[:2], jnp.float32), mask], axis=1)
    return shard(h, "batch", "res_seq", None), mask


def forward(params: dict, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            window: int = 0, remat: bool = True
            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Training/prefill forward.  Returns (logits, loss_mask, aux)."""
    h, mask = embed_inputs(params, batch, cfg)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, batch["frames"], cfg)

    aux_total = jnp.zeros((), jnp.float32)
    for kind, off, n in pattern_runs(cfg.block_pattern):
        if kind == "S":
            # Perf P4: shared blocks are applied UNROLLED (one param
            # set, many positions) — without remat all applications'
            # internals stay live for backward (~50 GB on zamba2);
            # checkpoint each application like the scanned blocks.
            def s_apply(p_, h_):
                out, _, aux_ = block_apply("S", p_, h_, positions, cfg,
                                           window, enc_out=enc_out)
                return out, aux_
            if remat:
                s_apply = jax.checkpoint(s_apply)
            for _ in range(n):
                h, aux = s_apply(params["shared"], h)
                aux_total += aux
            continue
        stacked = jax.tree_util.tree_map(
            lambda t: t[off:off + n], params["blocks"][kind])

        def body(carry, p_layer, kk=kind):
            x, at = carry
            x, _, aux = block_apply(kk, p_layer, x, positions, cfg,
                                    window, enc_out=enc_out)
            return (x, at + aux), None

        body_fn = jax.checkpoint(body) if remat else body
        (h, aux_total), _ = jax.lax.scan(body_fn, (h, aux_total), stacked)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    logits = h @ head.astype(h.dtype)
    return shard(logits, "batch", "seq", "vocab"), mask, aux_total


def loss_fn(params: dict, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            window: int = 0, remat: bool = True) -> jnp.ndarray:
    """Next-token cross-entropy (+ MoE aux)."""
    logits, mask, aux = forward(params, batch, cfg, window, remat)
    logits = logits[:, :-1].astype(jnp.float32)
    # targets: tokens shifted; modality positions are masked out
    tok = batch["tokens"]
    S_front = logits.shape[1] + 1 - tok.shape[1]   # prepended stub positions
    targets = tok
    tmask = mask[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    if S_front > 0:
        # predictions for text tokens start at position S_front - 1
        logp_text = logp[:, S_front - 1:S_front - 1 + tok.shape[1] - 1]
        tgt = targets[:, 1:]
        tm = jnp.ones(tgt.shape, jnp.float32)
    else:
        logp_text = logp
        tgt = targets[:, 1:]
        tm = tmask
    ll = jnp.take_along_axis(logp_text, tgt[..., None], axis=-1)[..., 0]
    ce = -(ll * tm).sum() / jnp.maximum(tm.sum(), 1.0)
    return ce + cfg.router_aux_weight * aux


# ---------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
               window: int = 0) -> dict:
    """Static-shape cache stacks, one entry per block kind."""
    counts = cfg.counts()
    cache: Dict[str, Any] = {}
    attn_len = min(max_len, window) if window else max_len
    for kind in "AMRS":
        n = counts.get(kind, 0)
        if not n:
            continue
        one = init_block_cache(cfg, kind, batch, attn_len, dtype)
        cache[kind] = jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t[None], (n,) + t.shape).copy(), one)
    if cfg.is_encoder_decoder:
        cache["enc_out"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                                     dtype)
    return cache


def decode_step(params: dict, cache: dict, tokens: jnp.ndarray,
                cache_index: jnp.ndarray, cfg: ModelConfig,
                window: int = 0) -> Tuple[jnp.ndarray, dict]:
    """One decode step.  tokens: [B, 1]; cache_index: scalar int32
    (next write position; with a window cache, positions are modulo the
    window — handled by the caller keeping cache_index < cache len)."""
    dt = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    h = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    positions = jnp.broadcast_to(cache_index[None, None], (B, 1))
    enc_out = cache.get("enc_out", None)
    if enc_out is not None:
        enc_out = enc_out.astype(dt)

    new_cache = dict(cache)
    for kind, off, n in pattern_runs(cfg.block_pattern):
        if kind == "S":
            # shared params; distinct cache per S position
            for i in range(n):
                cslice = jax.tree_util.tree_map(
                    lambda t: t[off + i], cache["S"])
                h, new_cs, _ = block_apply(
                    "S", params["shared"], h, positions, cfg, window,
                    cache=cslice, cache_index=cache_index, enc_out=enc_out)
                new_cache["S"] = jax.tree_util.tree_map(
                    lambda full, upd: full.at[off + i].set(upd),
                    new_cache["S"], new_cs)
            continue
        stacked_p = jax.tree_util.tree_map(
            lambda t: t[off:off + n], params["blocks"][kind])
        stacked_c = jax.tree_util.tree_map(
            lambda t: t[off:off + n], new_cache[kind])

        def body(x, pc, kk=kind):
            p_layer, c_layer = pc
            x, new_c, _ = block_apply(kk, p_layer, x, positions, cfg,
                                      window, cache=c_layer,
                                      cache_index=cache_index,
                                      enc_out=enc_out)
            return x, new_c

        h, upd = jax.lax.scan(body, h, (stacked_p, stacked_c))
        new_cache[kind] = jax.tree_util.tree_map(
            lambda full, u: full.at[off:off + n].set(u),
            new_cache[kind], upd)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    logits = h @ head.astype(h.dtype)
    return logits, new_cache


def param_count(params) -> int:
    return sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(params))
