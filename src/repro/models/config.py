"""Model configuration — one dataclass drives every assigned architecture.

The zoo is a single flexible decoder / encoder-decoder implementation;
family-specific behaviour (GQA vs MLA attention, dense vs MoE FFN,
Mamba2 / RWKV6 token mixing, hybrid interleave, modality frontends) is
selected by fields here.  ``configs/<arch>.py`` instantiates the exact
assigned configuration; ``reduced()`` derives the CPU smoke-test
variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int

    # ---- attention (decoder self-attention) ----
    attn_type: str = "gqa"         # gqa | mla | none
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0              # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0        # 0 = full attention

    # ---- MLA (MiniCPM3 / DeepSeek-style latent attention) ----
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- MoE ----
    num_experts: int = 0           # routed experts (0 = dense FFN)
    num_experts_padded: int = 0    # padded for mesh divisibility
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # ---- SSM / hybrid ----
    # block pattern: 'A' = attention block, 'M' = mamba2, 'R' = rwkv6,
    # 'S' = *shared*-parameter attention block (Zamba2).  Empty = all 'A'.
    block_pattern: str = ""
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # ---- encoder-decoder (Whisper) ----
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0           # stub audio frames (1500 for whisper)

    # ---- modality frontend stubs ----
    frontend: str = "none"         # none | vision_stub | audio_stub
    num_patch_tokens: int = 0      # VLM: image patches prepended

    # ---- distribution ----
    # fsdp: params sharded over the data axis too (required when a full
    # model replica does not fit a 16-chip model-parallel group).  The
    # paper's quantized delta aggregation then applies across the POD
    # axis only (see DESIGN.md §4).
    fsdp: bool = False

    # ---- numerics / misc ----
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # ---- citation (assignment requires source in brackets) ----
    source: str = ""

    # vocab padded up to a multiple of 256 so the vocab dim always
    # divides the 16-way model axis (embedding/lm_head params and
    # logits use the padded size; targets never reference pad ids)
    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab_size // 256) * 256

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.attn_type in ("gqa",) and self.num_heads:
            if self.head_dim == 0:
                object.__setattr__(self, "head_dim",
                                   self.d_model // self.num_heads)
        if self.num_experts and not self.num_experts_padded:
            object.__setattr__(self, "num_experts_padded", self.num_experts)
        if not self.block_pattern:
            object.__setattr__(self, "block_pattern", "A" * self.num_layers)
        if len(self.block_pattern) != self.num_layers:
            raise ValueError(
                f"{self.name}: block_pattern length "
                f"{len(self.block_pattern)} != num_layers {self.num_layers}")

    # ---- derived ----
    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.ssm_head_dim

    def counts(self) -> dict:
        """Block-type counts, for param accounting and docs."""
        return {c: self.block_pattern.count(c) for c in "AMRS"}

    def supports_decode(self) -> bool:
        return True  # every assigned arch has a decoder

    def supports_long_context(self) -> bool:
        """long_500k: native for SSM/hybrid; dense via sliding window;
        whisper (enc-dec) skipped — see DESIGN.md."""
        return not self.is_encoder_decoder

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant of the same family: 2 layers,
        d_model <= 512, <= 4 experts."""
        pat = self.block_pattern
        # keep family character: take first + a distinctive later block
        distinct = next((c for c in pat if c != pat[0]), pat[0])
        new_pat = pat[0] + distinct
        num_heads = min(self.num_heads, 4) if self.num_heads else 0
        d_model = 256
        kv = min(self.num_kv_heads, num_heads) if self.num_kv_heads else 0
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            block_pattern=new_pat,
            d_model=d_model,
            d_ff=512,
            vocab_size=512,
            num_heads=num_heads,
            num_kv_heads=kv,
            head_dim=64 if num_heads else 0,
            q_lora_rank=min(self.q_lora_rank, 128),
            kv_lora_rank=min(self.kv_lora_rank, 64),
            qk_nope_head_dim=32 if self.attn_type == "mla" else 0,
            qk_rope_head_dim=16 if self.attn_type == "mla" else 0,
            v_head_dim=32 if self.attn_type == "mla" else 0,
            num_experts=min(self.num_experts, 4),
            num_experts_padded=min(self.num_experts_padded, 4),
            num_shared_experts=min(self.num_shared_experts, 1),
            top_k=min(self.top_k, 2),
            moe_d_ff=min(self.moe_d_ff, 256) if self.moe_d_ff else 0,
            ssm_state_dim=min(self.ssm_state_dim, 16),
            ssm_head_dim=32 if self.ssm_state_dim else 64,
            ssm_chunk=32,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 64),
            num_patch_tokens=min(self.num_patch_tokens, 16),
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else 0,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned (seq_len, global_batch) workloads."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
