"""ModelSpec — the pytree-generic model contract for the FL stack.

The engine and the reference loop used to hard-code ``init_cnn(key,
cnn_cfg)`` / ``cnn_loss`` / ``cnn_accuracy``; everything downstream of
``init`` already operates on flattened pytrees, so federating any model
is a matter of naming these three callables.  :func:`as_model_spec`
keeps every existing call site working (a :class:`PaperCNNConfig`
passed positionally resolves to the paper CNN spec), and
:func:`model_spec_from_arch` turns any decoder-only config from
``repro.configs.registry`` into a federable spec — reduced geometry by
default, so the tiny-transformer/MoE smoke runs on the CPU runner.

Data contract: ``loss(params, x, y) -> scalar`` and
``accuracy(params, x, y) -> float`` where for LM specs ``x`` is a
[B, S] int token window and ``y`` the [B] next token after each window
(:func:`repro.data.synthetic.make_lm_dataset`); the LM loss is
next-token cross-entropy inside the window (``y`` rides along for the
accuracy probe).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from repro.configs.paper_cnn import PaperCNNConfig

from .cnn import cnn_accuracy, cnn_loss, init_cnn


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Everything the FL stack needs to federate one model family.

    ``init``: PRNGKey -> params pytree (any leaf dtypes — the flatten
    path round-trips them); ``loss``: (params, x, y) -> scalar (jit/
    grad-safe); ``accuracy``: (params, x, y) -> float (host-side eval,
    may loop over batches eagerly).  ``config`` keeps the source config
    around for sharding specs / layer-budget resolution / repr.
    """

    name: str
    init: Callable[[Any], Any]
    loss: Callable[[Any, Any, Any], Any]
    accuracy: Callable[[Any, Any, Any], float]
    config: Any = None


def as_model_spec(model) -> ModelSpec:
    """Resolve what callers pass in the engine's 4th slot to a ModelSpec.

    Accepts a ready :class:`ModelSpec` or a :class:`PaperCNNConfig`
    (the historical signature — every pre-existing call site).
    """
    if isinstance(model, ModelSpec):
        return model
    if isinstance(model, PaperCNNConfig):
        cfg = model
        return ModelSpec(
            name="paper-cnn",
            init=lambda key: init_cnn(key, cfg),
            loss=cnn_loss,
            accuracy=cnn_accuracy,
            config=cfg)
    raise TypeError(
        f"expected a ModelSpec or PaperCNNConfig, got {type(model).__name__}"
        " — wrap custom models in repro.fl.ModelSpec(init, loss, accuracy)")


def model_spec_from_arch(arch_id: str, reduced: bool = True) -> ModelSpec:
    """Federate a registry transformer: ``repro.configs.registry`` id ->
    ModelSpec over :mod:`repro.models.transformer`.

    ``reduced=True`` (default) shrinks to the config's CPU-testable
    geometry (2 layers, d_model 256, vocab 512) — the federated smoke
    target; ``reduced=False`` federates the full architecture (only
    sensible with ``repro.dist`` sharding underneath).
    """
    from repro.configs.registry import get_config
    from repro.models.transformer import forward, init_model, loss_fn

    cfg = get_config(arch_id)
    if reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder or cfg.frontend != "none":
        raise ValueError(
            f"model_spec_from_arch supports decoder-only token models; "
            f"{arch_id!r} has frontend={cfg.frontend!r} "
            f"is_encoder_decoder={cfg.is_encoder_decoder}")

    def init(key):
        return init_model(key, cfg)

    def loss(params, x, y):
        del y   # next-token CE over the window; y feeds accuracy only
        return loss_fn(params, {"tokens": x}, cfg, remat=False)

    def accuracy(params, x, y, batch: int = 256) -> float:
        correct, n = 0, x.shape[0]
        for i in range(0, n, batch):
            logits, _, _ = forward(params, {"tokens": x[i:i + batch]},
                                   cfg, remat=False)
            pred = jnp.argmax(logits[:, -1, :], axis=-1)
            correct += int(jnp.sum(pred == y[i:i + batch]))
        return correct / n

    return ModelSpec(name=cfg.name, init=init, loss=loss,
                     accuracy=accuracy, config=cfg)
