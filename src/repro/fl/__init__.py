from .cnn import cnn_accuracy, cnn_forward, cnn_loss, init_cnn
from .loop import (FLConfig, FLResult, RoundLog, run_fl,
                   run_fl_sequential)
from .models import ModelSpec, as_model_spec, model_spec_from_arch
