"""Algorithm 1 — FL over CFmMIMO with adaptive mixed-resolution
quantization and straggler-mitigating power control.

Per global round t:
  1. users run L local AdaGrad iterations from w_{t-1} (eq. 2);
  2. each quantizes its delta (eq. 7) and reports its bit count b_t^j;
  3. the server solves the power-control problem (eq. 14) for p_t;
  4. users "transmit" — per-user uplink latency ell_t^j = b_t^j / R_t^j
     (eq. 12); the round costs max_j ell_t^j + computation time;
  5. server updates w_t = w_{t-1} + sum_j rho_j recon_j (eq. 3).

The wireless part is simulated through the closed-form rate model;
training is real (jit-compiled local AdaGrad on the synthetic image
tasks).  Supports every quantizer and power controller for the paper's
benchmark tables, plus a total-latency budget -> T_max accounting.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import (ChannelRealization, computation_latency)
from repro.core.power.base import PowerController
from repro.core.quantize import Quantizer
from repro.core.quantize.base import flatten_pytree, unflatten_pytree
from repro.data.federated import user_fractions, validate_shards
from repro.data.synthetic import ImageDataset

from .cnn import cnn_loss
from .models import ModelSpec, as_model_spec


@dataclasses.dataclass
class FLConfig:
    L: int = 5                    # local AdaGrad iterations
    T: int = 100                  # global rounds
    batch_size: int = 64          # xi_j
    alpha: float = 0.03           # AdaGrad step size
    eps_a: float = 1e-8
    eval_every: int = 5
    latency_budget_s: Optional[float] = None   # stop when exceeded
    seed: int = 0
    dataset_size_for_comp: int = 50_000        # ell_c inputs [27]


@dataclasses.dataclass
class RoundLog:
    round: int
    bits_per_user: np.ndarray
    uplink_latency_s: float       # async rounds: event-clock duration
    comp_latency_s: float
    cum_latency_s: float
    mean_s: float                 # mean high-res fraction (aux)
    test_acc: Optional[float]
    # straggler/async accounting (defaults keep pre-async callers and
    # the sequential reference loop unchanged)
    straggler_gap_s: float = 0.0          # slowest - median completion
    mean_staleness: float = 0.0           # over aggregated arrivals
    effective_participation: float = 1.0  # aggregated users / K
    dropped_uploads: int = 0              # stale- + churn-dropped
    # resilience accounting (DESIGN.md §14; defaults keep pre-PR-10
    # code paths unchanged)
    quarantined_users: int = 0            # guard-masked payloads
    power_fallbacks: int = 0              # solver fallback stages used


@dataclasses.dataclass
class FLResult:
    params: Any
    logs: List[RoundLog]
    rounds_completed: int         # T_max under the budget

    @property
    def final_acc(self) -> float:
        accs = [l.test_acc for l in self.logs if l.test_acc is not None]
        return accs[-1] if accs else float("nan")

    def mean_bits(self) -> float:
        return float(np.mean([np.mean(l.bits_per_user) for l in self.logs]))

    def mean_s(self) -> float:
        return float(np.mean([l.mean_s for l in self.logs]))


def local_adagrad(params, xs, ys, L: int, alpha: float, loss=cnn_loss):
    """L AdaGrad steps on stacked minibatches xs [L,b,...], ys [L,b].

    Pure function: the sequential path jits it per user below; the
    vectorized engine (repro.sim.engine) vmaps it over all K users'
    stacked minibatches inside one jitted round step.  ``loss`` is any
    ``(params, x, y) -> scalar`` callable (static under jit) — the
    paper CNN's by default, a :class:`ModelSpec`'s for the
    pytree-generic engine.
    """
    g0 = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)

    def step(carry, batch):
        w, g = carry
        x, y = batch
        grads = jax.grad(loss)(w, x, y)
        g = jax.tree_util.tree_map(lambda a, d: a + d * d, g, grads)
        w = jax.tree_util.tree_map(
            lambda p, d, a: p - alpha / jnp.sqrt(a + 1e-8) * d,
            w, grads, g)
        return (w, g), None

    (w, _), _ = jax.lax.scan(step, (params, g0), (xs, ys))
    return w


_local_adagrad = jax.jit(local_adagrad, static_argnums=(3, 4, 5))


def run_fl(dataset: ImageDataset, test: ImageDataset,
           shards: List[np.ndarray], model,
           quantizer: Quantizer, power: Optional[PowerController],
           chan: Optional[ChannelRealization], fl: FLConfig,
           verbose: bool = False, engine: Optional[Any] = None
           ) -> FLResult:
    """Algorithm 1 — compatibility entry point.

    Delegates to the vectorized engine (repro.sim.engine), which runs
    all K users' local iterations in one jit dispatch per round and
    reproduces this module's sequential reference bit-for-bit at fixed
    seed (tests/test_sim_engine.py).  The engine stacks user batches to
    [K, L, b], which requires a uniform per-user batch size; when some
    shard is smaller than batch_size (ragged takes), this falls back to
    the sequential loop so the per-user ``min(batch_size, |D_j|)``
    semantics — and bit-for-bit reproducibility — are preserved
    unconditionally.  power/chan None => latency not simulated (pure
    convergence experiments, e.g. Fig. 2 / Table II).  ``engine`` is an
    optional repro.sim.EngineConfig (e.g. with a mesh to shard the
    user axis across devices); the ragged-shard fallback ignores it.

    ``model`` is a :class:`repro.fl.ModelSpec` or (the historical
    signature) a :class:`PaperCNNConfig`.
    """
    validate_shards(shards)
    if min(len(s) for s in shards) < fl.batch_size:
        return run_fl_sequential(dataset, test, shards, model,
                                 quantizer, power, chan, fl,
                                 verbose=verbose)
    from repro.sim.engine import VectorizedFLEngine

    eng = VectorizedFLEngine(dataset, test, shards, model, quantizer,
                             power, chan, fl, engine=engine)
    return eng.run(verbose=verbose)


def run_fl_sequential(dataset: ImageDataset, test: ImageDataset,
                      shards: List[np.ndarray], model,
                      quantizer: Quantizer, power: Optional[PowerController],
                      chan: Optional[ChannelRealization], fl: FLConfig,
                      verbose: bool = False) -> FLResult:
    """Algorithm 1, one user at a time — the original seed loop.

    Kept as the numerical reference for the engine equivalence test and
    the dispatch-overhead baseline in benchmarks/sim_engine.py: per
    round it pays one jit dispatch per user for the local AdaGrad run
    plus an eager quantizer call per user."""
    spec_m = as_model_spec(model)
    validate_shards(shards)
    K = len(shards)
    rho = user_fractions(shards)
    rng = np.random.default_rng(fl.seed)
    key = jax.random.PRNGKey(fl.seed)
    params = spec_m.init(key)
    flat0, spec = flatten_pytree(params)
    d = flat0.size
    qstates = [quantizer.init_state(d) for _ in range(K)]

    comp_lat = computation_latency(fl.L, fl.dataset_size_for_comp, K)
    logs: List[RoundLog] = []
    cum_latency = 0.0
    rounds_done = 0

    for t in range(1, fl.T + 1):
        recons = []
        bits = np.zeros(K)
        s_fracs = []
        for j in range(K):
            shard = shards[j]
            take = min(fl.batch_size, len(shard))
            sel = np.stack([rng.choice(shard, take, replace=False)
                            for _ in range(fl.L)])
            xs = jnp.asarray(dataset.x[sel])
            ys = jnp.asarray(dataset.y[sel])
            w_j = _local_adagrad(params, xs, ys, fl.L, fl.alpha,
                                 spec_m.loss)
            delta = jax.tree_util.tree_map(lambda a, b: a - b, w_j, params)
            flat, _ = flatten_pytree(delta)
            res, qstates[j] = quantizer(flat, qstates[j])
            recons.append(res.recon)
            bits[j] = float(res.bits)
            s_fracs.append(float(res.aux.get("s", 1.0)))

        # eq. (3): weighted aggregation of reconstructions
        agg = sum(r * w for r, w in zip(recons, rho))
        upd = unflatten_pytree(agg, spec)
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, upd)

        # power control + latency accounting
        if power is not None and chan is not None:
            sol = power.solve(chan, np.maximum(bits, 1.0))
            uplink = sol.straggler_latency
        else:
            uplink = 0.0
        cum_latency += uplink + comp_lat

        acc = None
        if t % fl.eval_every == 0 or t == fl.T:
            acc = spec_m.accuracy(params, jnp.asarray(test.x),
                                  jnp.asarray(test.y))
        logs.append(RoundLog(t, bits, uplink, comp_lat, cum_latency,
                             float(np.mean(s_fracs)), acc))
        rounds_done = t
        if verbose and acc is not None:
            print(f"[round {t:4d}] acc={acc:.4f} "
                  f"bits/user={bits.mean():.3e} cum_lat={cum_latency:.2f}s")
        if (fl.latency_budget_s is not None
                and cum_latency >= fl.latency_budget_s):
            break

    return FLResult(params=params, logs=logs, rounds_completed=rounds_done)
