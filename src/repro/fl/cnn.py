"""The paper's CNN (§IV) in pure JAX: Conv(32,3x3)+ReLU -> MaxPool(2x2)
-> Flatten -> Dense(64)+ReLU -> Dense(n_classes)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.paper_cnn import PaperCNNConfig


def init_cnn(key, cfg: PaperCNNConfig) -> Dict[str, jnp.ndarray]:
    k1, k2, k3 = jax.random.split(key, 3)
    fan1 = 3 * 3 * cfg.channels
    return {
        "conv_w": jax.random.normal(k1, (3, 3, cfg.channels,
                                         cfg.conv_filters)) / jnp.sqrt(fan1),
        "conv_b": jnp.zeros((cfg.conv_filters,)),
        "dense1_w": jax.random.normal(k2, (cfg.flat_dim, cfg.dense_units))
        / jnp.sqrt(cfg.flat_dim),
        "dense1_b": jnp.zeros((cfg.dense_units,)),
        "dense2_w": jax.random.normal(k3, (cfg.dense_units, cfg.n_classes))
        / jnp.sqrt(cfg.dense_units),
        "dense2_b": jnp.zeros((cfg.n_classes,)),
    }


def cnn_forward(params: Dict[str, jnp.ndarray], x: jnp.ndarray
                ) -> jnp.ndarray:
    """x: [B, H, W, C] -> logits [B, n_classes]."""
    h = jax.lax.conv_general_dilated(
        x, params["conv_w"], window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["conv_b"]
    h = jax.nn.relu(h)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["dense1_w"] + params["dense1_b"])
    return h @ params["dense2_w"] + params["dense2_b"]


def cnn_loss(params, x, y) -> jnp.ndarray:
    logits = cnn_forward(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def cnn_accuracy(params, x, y, batch: int = 512) -> float:
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = cnn_forward(params, x[i:i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i:i + batch]))
    return correct / x.shape[0]
