"""Round-log aggregation — the numbers the benchmark tables consume.

``summarize_logs`` reduces a list of per-round RoundLog records (from
either the vectorized engine or the sequential reference loop) to the
scalar metrics reported in the paper's tables: best/final accuracy,
rounds completed (T_max under a budget), mean payload bits, mean
high-resolution fraction s, cumulative latency and straggler
percentiles — plus the straggler-gap (slowest minus median upload
completion) and async-round columns (mean staleness over aggregated
arrivals, effective participation, dropped-upload totals), which stay
at their sync defaults for lockstep runs.

``summarize_replicates`` lifts that row over the Monte-Carlo replicate
axis: each replicate's log list is summarized independently, every
metric column becomes the across-replicate mean under its original
name (so downstream table code needs no change), and a ``<metric>_ci95``
column carries the normal-approximation 95% confidence half-width
``1.96 * std(ddof=1) / sqrt(R)`` (0 at R = 1 — a point estimate has no
width).  The mean is the plain ``np.mean`` of the per-replicate
summaries, which is what tests/test_mc_replicates.py pins host-side.
"""
from __future__ import annotations

import csv
import os
import tempfile
from typing import Dict, Iterable, List, Sequence


def summarize_logs(logs: List) -> Dict[str, float]:
    """Aggregate a FLResult.logs list into one metrics row."""
    import numpy as np

    accs = [l.test_acc for l in logs if l.test_acc is not None]
    uplinks = np.array([l.uplink_latency_s for l in logs])
    bits = np.array([np.mean(l.bits_per_user) for l in logs])
    return {
        "rounds": float(logs[-1].round) if logs else 0.0,
        "best_acc": float(max(accs)) if accs else float("nan"),
        "final_acc": float(accs[-1]) if accs else float("nan"),
        "mean_bits_per_user": float(bits.mean()) if logs else float("nan"),
        "mean_s": float(np.mean([l.mean_s for l in logs]))
        if logs else float("nan"),
        "total_latency_s": float(logs[-1].cum_latency_s)
        if logs else 0.0,
        "mean_uplink_s": float(uplinks.mean()) if logs else 0.0,
        "p95_uplink_s": float(np.percentile(uplinks, 95))
        if logs else 0.0,
        # straggler/async columns (PR 7): getattr defaults keep logs
        # from pre-async code paths summarizable
        "mean_straggler_gap_s": float(np.mean(
            [getattr(l, "straggler_gap_s", 0.0) for l in logs]))
        if logs else 0.0,
        "mean_staleness": float(np.mean(
            [getattr(l, "mean_staleness", 0.0) for l in logs]))
        if logs else 0.0,
        "effective_participation": float(np.mean(
            [getattr(l, "effective_participation", 1.0) for l in logs]))
        if logs else float("nan"),
        "dropped_uploads": float(sum(
            getattr(l, "dropped_uploads", 0) for l in logs)),
        # resilience totals (PR 10): guard-quarantined payloads and
        # power-solver fallback stages consumed across the run
        "quarantined_users": float(sum(
            getattr(l, "quarantined_users", 0) for l in logs)),
        "power_fallbacks": float(sum(
            getattr(l, "power_fallbacks", 0) for l in logs)),
    }


def summarize_replicates(replicate_logs: Sequence[List]
                         ) -> Dict[str, float]:
    """Reduce R replicates' log lists to mean + ci95 columns.

    Every ``summarize_logs`` metric appears under its own name as the
    across-replicate mean, plus ``<metric>_ci95`` (1.96 * standard
    error; 0.0 at R = 1) and a ``replicates`` count column.  NaN
    metrics (e.g. accuracy in a no-eval window) propagate as NaN means.
    """
    import numpy as np

    if not replicate_logs:
        raise ValueError("need at least one replicate")
    rows = [summarize_logs(logs) for logs in replicate_logs]
    R = len(rows)
    out: Dict[str, float] = {}
    for key in rows[0]:
        vals = np.array([row[key] for row in rows], dtype=np.float64)
        out[key] = float(np.mean(vals))
        out[key + "_ci95"] = float(
            1.96 * np.std(vals, ddof=1) / np.sqrt(R)) if R > 1 else 0.0
    out["replicates"] = float(R)
    return out


# max_p is filled by the batched phy driver (largest power coefficient
# allocated to any user across the run; <= 1 means transmit power
# <= p_max) and left blank by the host-solve path.
METRIC_FIELDS = ["rounds", "best_acc", "final_acc", "mean_bits_per_user",
                 "mean_s", "total_latency_s", "mean_uplink_s",
                 "p95_uplink_s", "mean_straggler_gap_s",
                 "mean_staleness", "effective_participation",
                 "dropped_uploads", "quarantined_users",
                 "power_fallbacks", "resumed_from_round", "max_p"]

# the replicated driver's extra columns (summarize_replicates); written
# only when some row carries them, so unreplicated sweep CSVs keep
# their schema.  max_p and resumed_from_round are driver-filled
# (outside the per-replicate summaries), so they carry no ci95.
REPLICATE_FIELDS = ["replicates"] + [
    f + "_ci95" for f in METRIC_FIELDS
    if f not in ("max_p", "resumed_from_round")]


def write_metrics_csv(rows: Iterable[Dict], path: str) -> None:
    """Write sweep rows (scenario/quantizer/power + metrics) to CSV."""
    rows = list(rows)
    if not rows:
        return
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fields = ["scenario", "quantizer", "power"] + METRIC_FIELDS
    if any(f in row for f in REPLICATE_FIELDS for row in rows):
        fields += REPLICATE_FIELDS
    # atomic: a reader (or a kill -9 mid-write) never sees a torn CSV —
    # the temp file lands in the target directory so os.replace stays
    # a same-filesystem rename
    fd, tmp = tempfile.mkstemp(
        dir=os.path.dirname(path) or ".", suffix=".csv.tmp")
    try:
        with os.fdopen(fd, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=fields,
                               extrasaction="ignore")
            w.writeheader()
            w.writerows(rows)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise
