"""Round-log aggregation — the numbers the benchmark tables consume.

``summarize_logs`` reduces a list of per-round RoundLog records (from
either the vectorized engine or the sequential reference loop) to the
scalar metrics reported in the paper's tables: best/final accuracy,
rounds completed (T_max under a budget), mean payload bits, mean
high-resolution fraction s, cumulative latency and straggler
percentiles.
"""
from __future__ import annotations

import csv
import os
from typing import Dict, Iterable, List


def summarize_logs(logs: List) -> Dict[str, float]:
    """Aggregate a FLResult.logs list into one metrics row."""
    import numpy as np

    accs = [l.test_acc for l in logs if l.test_acc is not None]
    uplinks = np.array([l.uplink_latency_s for l in logs])
    bits = np.array([np.mean(l.bits_per_user) for l in logs])
    return {
        "rounds": float(logs[-1].round) if logs else 0.0,
        "best_acc": float(max(accs)) if accs else float("nan"),
        "final_acc": float(accs[-1]) if accs else float("nan"),
        "mean_bits_per_user": float(bits.mean()) if logs else float("nan"),
        "mean_s": float(np.mean([l.mean_s for l in logs]))
        if logs else float("nan"),
        "total_latency_s": float(logs[-1].cum_latency_s)
        if logs else 0.0,
        "mean_uplink_s": float(uplinks.mean()) if logs else 0.0,
        "p95_uplink_s": float(np.percentile(uplinks, 95))
        if logs else 0.0,
    }


# max_p is filled by the batched phy driver (largest power coefficient
# allocated to any user across the run; <= 1 means transmit power
# <= p_max) and left blank by the host-solve path.
METRIC_FIELDS = ["rounds", "best_acc", "final_acc", "mean_bits_per_user",
                 "mean_s", "total_latency_s", "mean_uplink_s",
                 "p95_uplink_s", "max_p"]


def write_metrics_csv(rows: Iterable[Dict], path: str) -> None:
    """Write sweep rows (scenario/quantizer/power + metrics) to CSV."""
    rows = list(rows)
    if not rows:
        return
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fields = ["scenario", "quantizer", "power"] + METRIC_FIELDS
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields, extrasaction="ignore")
        w.writeheader()
        w.writerows(rows)
