"""Named simulation scenarios for the vectorized engine.

A Scenario is a declarative bundle of (dataset, partition, FL
hyper-parameters, CFmMIMO network shape, engine behaviour) that
``build_problem`` turns into concrete engine inputs.  The registry
covers the paper's operating points (Tables II-III) plus workloads the
sequential seed loop could not reach at useful speed:

* ``churn-*``        — per-round partial participation (user churn);
* ``monte-carlo-*``  — fresh large-scale channel realization per round
  (Monte-Carlo averaging over fading geometry, as in Vu et al.);
* ``hetero-data``    — Zipf-distributed shard sizes (device
  heterogeneity, as in Mahmoudi et al.);
* ``grid-*``         — K x M network-shape sweep points;
* ``async-*``        — asynchronous straggler-faithful rounds (event
  clock, bounded-staleness buffer; ``async_scenarios`` generates the
  alpha x deadline-quantile x buffer-depth sweep axes).

Every scenario carries paper-scale parameters; sweep/quick mode scales
K, T and the dataset down uniformly so the full grid runs on a laptop
CPU in minutes (`Scenario.scaled(quick=True)`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.paper_cnn import CIFAR10, CIFAR100, FASHION, PaperCNNConfig
from repro.core.channel import CFmMIMOConfig, make_channel
from repro.core.quantize import LayerBudget
from repro.data import (make_image_classification, partition_dirichlet,
                        partition_iid, partition_powerlaw)

from .engine import EngineConfig, StalenessConfig

_DATASETS: Dict[str, Tuple[PaperCNNConfig, int]] = {
    "cifar10-syn": (CIFAR10, 10),
    "cifar100-syn": (CIFAR100, 100),
    "fashion-syn": (FASHION, 10),
}


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    # model axis: "paper-cnn" (the default — scn.dataset picks the CNN
    # geometry) or any repro.configs.registry arch id ("qwen3-14b",
    # "qwen2-moe", ...) federated at reduced geometry over the
    # synthetic next-token task (repro.fl.model_spec_from_arch)
    model: str = "paper-cnn"
    seq_len: int = 32                    # LM window length (model != cnn)
    # per-layer mixed-resolution budget (repro.core.quantize.LayerBudget)
    # threaded onto WirePath.budget; None/uniform keeps the global path
    budget: Optional[object] = None
    # data
    dataset: str = "cifar10-syn"
    n_train: int = 8000
    n_test: int = 1600
    partition: str = "iid"               # iid | dirichlet | powerlaw
    dirichlet_alpha: float = 0.3
    powerlaw_exp: float = 1.3
    # FL (paper Table I / §IV defaults)
    K: int = 20
    T: int = 100
    L: int = 5
    batch_size: int = 48
    lr: float = 0.01
    eval_every: Optional[int] = None     # None => max(1, T // 5)
    latency_budget_s: Optional[float] = None
    # CFmMIMO network (None M => no channel/power simulation)
    M: Optional[int] = 16
    N: int = 4
    # engine behaviour
    participation: float = 1.0
    redraw_channel_every: int = 0
    # wire-path plane spelled in the legacy vocabulary ("dense" |
    # "signplane" | "wire"); engine_config() maps it onto the unified
    # WirePath spec WITHOUT the deprecation warning (a still-supported
    # declarative field, not a legacy engine knob)
    aggregation: str = "dense"
    fused: bool = True               # production sweeps run fully fused
    # streaming cohorts (DESIGN.md §12): scan the K users in cohorts of
    # this size inside the fused packed-plane step, so device residency
    # scales with the cohort, not K.  Requires aggregation="wire".
    # None keeps the fully vectorized step bit-for-bit.
    cohort_size: Optional[int] = None
    # two-level AP-cluster hierarchy: partial on-device aggregates per
    # contiguous user group, host-combined.  Requires cohort_size.
    clusters: int = 1
    seed: int = 0
    # Monte-Carlo replicate axis (DESIGN.md section 8): > 1 makes the
    # batched sweep driver run this many independent trajectories per
    # cell — distinct data/churn RNG streams and channel realizations,
    # vmapped through one jitted train step per round — and report
    # mean/ci95 summaries.  1 = point estimate (unreplicated driver).
    replicates: int = 1
    # Asynchronous rounds (DESIGN.md section 11): per-user upload
    # completion times govern aggregation.  async_mode=True with
    # neither deadline set is the documented sync reduction (runs the
    # lockstep path bit-for-bit).  deadline_quantile closes each round
    # at that quantile of the pending completion times;
    # staleness_alpha weighs arrivals by (1+staleness)^-alpha;
    # max_staleness bounds the in-flight buffer depth.
    async_mode: bool = False
    deadline_s: Optional[float] = None
    deadline_quantile: Optional[float] = None
    staleness_alpha: float = 0.0
    max_staleness: int = 2

    def scaled(self, quick: bool = True) -> "Scenario":
        """Quick-mode variant: reduced K/T/data for CPU CI runs."""
        if not quick:
            return self
        return dataclasses.replace(
            self, K=min(self.K, 8), T=min(self.T, 10),
            n_train=min(self.n_train, 2000), n_test=min(self.n_test, 400),
            batch_size=min(self.batch_size, 32))

    @property
    def effective_eval_every(self) -> int:
        return self.eval_every if self.eval_every is not None \
            else max(1, self.T // 5)

    def engine_config(self) -> EngineConfig:
        from repro.kernels import from_aggregation
        # map the declarative aggregation field onto the unified spec
        # silently (from_aggregation's warning is for legacy engine
        # call sites, not this still-supported scenario field)
        wp = from_aggregation(self.aggregation, warn=False)
        if self.cohort_size is not None or self.clusters > 1:
            wp = dataclasses.replace(wp, cohort_size=self.cohort_size,
                                     clusters=self.clusters)
        if self.budget is not None:
            wp = dataclasses.replace(wp, budget=self.budget)
        return EngineConfig(wire=wp,
                            fused=self.fused,
                            participation=self.participation,
                            redraw_channel_every=self.redraw_channel_every,
                            channel_seed=self.seed,
                            async_mode=self.async_mode,
                            staleness=StalenessConfig(
                                deadline_s=self.deadline_s,
                                deadline_quantile=self.deadline_quantile,
                                alpha=self.staleness_alpha,
                                max_staleness=self.max_staleness))

    @property
    def async_active(self) -> bool:
        """Mirrors EngineConfig.async_active: the batched driver needs
        this before any engine exists (async trajectories depend on the
        power controller, so tracks cannot be shared across cells)."""
        return self.engine_config().async_active


def build_problem(scn: Scenario):
    """(train, test, shards, model, chan) for a scenario.

    ``model`` is what the engine's 4th argument accepts: the scenario's
    :class:`PaperCNNConfig` for ``model="paper-cnn"`` (the historical
    tuple, so pre-existing unpackers keep working) or a
    :class:`repro.fl.ModelSpec` for a registry arch id, paired with the
    synthetic next-token dataset (:func:`make_lm_dataset`).
    """
    if scn.model != "paper-cnn":
        from repro.data.synthetic import make_lm_dataset
        from repro.fl.models import model_spec_from_arch

        spec = model_spec_from_arch(scn.model)
        full = make_lm_dataset(
            n_samples=scn.n_train + scn.n_test, seq_len=scn.seq_len,
            vocab=spec.config.vocab_size, seed=scn.seed)
        train = dataclasses.replace(full, x=full.x[:scn.n_train],
                                    y=full.y[:scn.n_train])
        test = dataclasses.replace(full, x=full.x[scn.n_train:],
                                   y=full.y[scn.n_train:])
        model = spec
    else:
        if scn.dataset not in _DATASETS:
            raise KeyError(f"unknown dataset {scn.dataset!r}; "
                           f"have {list(_DATASETS)}")
        cnn_cfg, n_classes = _DATASETS[scn.dataset]
        full = make_image_classification(
            n_samples=scn.n_train + scn.n_test, hw=cnn_cfg.input_hw,
            channels=cnn_cfg.channels, n_classes=n_classes, seed=scn.seed)
        train = dataclasses.replace(full, x=full.x[:scn.n_train],
                                    y=full.y[:scn.n_train])
        test = dataclasses.replace(full, x=full.x[scn.n_train:],
                                   y=full.y[scn.n_train:])
        model = cnn_cfg

    if scn.partition == "iid":
        shards = partition_iid(train, scn.K, seed=scn.seed)
    elif scn.partition == "dirichlet":
        shards = partition_dirichlet(train, scn.K,
                                     alpha=scn.dirichlet_alpha,
                                     seed=scn.seed)
    elif scn.partition == "powerlaw":
        shards = partition_powerlaw(train, scn.K, exponent=scn.powerlaw_exp,
                                    seed=scn.seed)
    else:
        raise KeyError(f"unknown partition {scn.partition!r}")

    chan = None
    if scn.M is not None:
        chan = make_channel(CFmMIMOConfig(M=scn.M, N=scn.N, K=scn.K),
                            seed=scn.seed)
    return train, test, shards, model, chan


# ----------------------------------------------------------- registry
SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(scn: Scenario) -> Scenario:
    if scn.name in SCENARIOS:
        raise KeyError(f"scenario {scn.name!r} already registered")
    SCENARIOS[scn.name] = scn
    return scn


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}")
    return SCENARIOS[name]


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def grid_scenarios(Ks=(10, 20, 40), Ms=(16, 36, 64),
                   base: Optional[Scenario] = None) -> List[Scenario]:
    """K x M network-shape sweep points (registered on first call with
    default arguments via the module-level loop below)."""
    base = base or Scenario(
        name="grid-base", description="K x M sweep point",
        partition="dirichlet", T=20)
    out = []
    for K in Ks:
        for M in Ms:
            out.append(dataclasses.replace(
                base, name=f"grid-K{K}-M{M}",
                description=f"network-shape sweep point K={K}, M={M}",
                K=K, M=M))
    return out


def async_scenarios(alphas=(0.0, 1.0), quantiles=(0.5, 0.9),
                    depths=(1, 2), base: Optional[Scenario] = None
                    ) -> List[Scenario]:
    """The staleness sweep axes: alpha x deadline-quantile x
    buffer-depth variants of ``base`` (default: the ``async-q50``
    operating point).  Returned UNREGISTERED — pass the Scenario
    objects straight to run_grid / run_grid_batched (both accept
    instances as well as registry names)."""
    base = base or SCENARIOS.get("async-q50") or Scenario(
        name="async-base", description="async sweep point",
        K=20, T=40, async_mode=True, deadline_quantile=0.5)
    out = []
    for alpha in alphas:
        for q in quantiles:
            for depth in depths:
                out.append(dataclasses.replace(
                    base, name=f"async-a{alpha:g}-q{q:g}-d{depth}",
                    description=(f"async sweep point alpha={alpha:g}, "
                                 f"deadline quantile {q:g}, buffer "
                                 f"depth {depth}"),
                    async_mode=True, deadline_s=None,
                    deadline_quantile=q, staleness_alpha=alpha,
                    max_staleness=depth))
    return out


register_scenario(Scenario(
    name="paper-table2",
    description="Table II operating point: K=20, L=5, IID/convergence "
                "(no latency simulation)",
    M=None, T=100, K=20, batch_size=48))

register_scenario(Scenario(
    name="paper-table2-noniid",
    description="Table II non-IID: Dirichlet(0.3) label skew",
    M=None, T=100, K=20, partition="dirichlet", batch_size=48))

register_scenario(Scenario(
    name="paper-table3",
    description="Table III operating point: K=40 non-IID over the "
                "CFmMIMO uplink with a total-latency budget",
    K=40, T=60, partition="dirichlet", batch_size=32))

register_scenario(Scenario(
    name="churn-0.7",
    description="user churn: every user independently participates in "
                "a round w.p. 0.7; aggregation weights renormalized",
    K=20, T=40, partition="dirichlet", participation=0.7))

register_scenario(Scenario(
    name="monte-carlo-channel",
    description="Monte-Carlo fading geometry: fresh large-scale "
                "realization every round (Vu et al. style averaging)",
    K=20, T=40, redraw_channel_every=1))

register_scenario(Scenario(
    name="monte-carlo-replicated",
    description="Monte-Carlo replicate axis: 8 independent trajectories "
                "(distinct channel realizations + data/churn RNG "
                "streams) vmapped through one train step per round; "
                "summaries report mean +- ci95",
    K=20, T=40, replicates=8))

register_scenario(Scenario(
    name="hetero-data",
    description="Zipf(1.3) shard sizes: heterogeneous per-user data "
                "loads (Mahmoudi et al. style device heterogeneity)",
    K=20, T=40, partition="powerlaw"))

register_scenario(Scenario(
    name="signplane-wire",
    description="paper default but aggregating through the Pallas "
                "signpack/sign_dequant_reduce wire format",
    M=None, K=20, T=40, aggregation="signplane"))

register_scenario(Scenario(
    name="fused-wire",
    description="paper default on the fully fused quantize-to-wire "
                "path: mixed-res encode, packed planes and weighted "
                "dequant-reduce all in the streaming kernel suite "
                "(kernels/mixed_res.py, DESIGN.md section 9)",
    M=None, K=20, T=40, aggregation="wire"))

register_scenario(Scenario(
    name="cohort-wire",
    description="fused-wire with the user axis streamed in cohorts of "
                "8: each scan chunk trains + packs 8 users and folds "
                "into the carried [d] accumulator, so the dense [K, d] "
                "gradient stack never exists (DESIGN.md section 12); "
                "bit-for-bit with fused-wire on the parity suite",
    M=None, K=20, T=40, aggregation="wire", cohort_size=8))

register_scenario(Scenario(
    name="cohort-hierarchy",
    description="two-level cell-free hierarchy: 4 AP-cluster groups "
                "each aggregate their users' packed planes on device "
                "(cohorts of 8), partial [d] aggregates combined "
                "host-ordered — the 10^4-10^5-user scaling story",
    M=None, K=20, T=40, aggregation="wire", cohort_size=8, clusters=4))

register_scenario(Scenario(
    name="async-q50",
    description="asynchronous rounds: each round closes at the median "
                "pending completion time; misses wait in a depth-2 "
                "staleness buffer with (1+s)^-0.5 down-weighting",
    K=20, T=40, async_mode=True, deadline_quantile=0.5,
    staleness_alpha=0.5, max_staleness=2))

register_scenario(Scenario(
    name="async-churn",
    description="async rounds under user churn (participation 0.7): "
                "users dropping mid-upload are evicted from the "
                "staleness buffer, never aggregated",
    K=20, T=40, async_mode=True, deadline_quantile=0.5,
    staleness_alpha=1.0, max_staleness=2, participation=0.7,
    partition="dirichlet"))

register_scenario(Scenario(
    name="transformer-fused",
    description="federate the reduced qwen3-14b transformer (2 layers, "
                "d_model 256, vocab 512, ~1.6M params) over the "
                "synthetic next-token task on the fused packed wire "
                "path — the pytree-generic engine's smoke point",
    model="qwen3-14b", M=None, K=4, T=2, L=1, batch_size=8,
    n_train=256, n_test=64, aggregation="wire", eval_every=2))

register_scenario(Scenario(
    name="layer-budget-wire",
    description="paper default under a per-layer budget: norm-like "
                "leaves keep a fine grid (b=12, lambda 0.1), matmul "
                "leaves a coarse one (b=6, lambda 0.3); payload bits "
                "are the exact per-segment sum (DESIGN.md section 13)",
    M=None, K=20, T=40, aggregation="wire",
    budget=LayerBudget.by_group(norm=(0.1, 12), matmul=(0.3, 6))))

register_scenario(Scenario(
    name="async-sync-reduction",
    description="async_mode=True with no deadline — the documented "
                "sync reduction: runs the lockstep engine bit-for-bit "
                "(the parity test's operating point)",
    K=20, T=40, async_mode=True))

for _scn in grid_scenarios():
    register_scenario(_scn)
