"""Batched-phy sweep driver — one power solve per round for a grid.

``run_grid`` steps every (scenario, quantizer, power) cell's engine to
completion one cell at a time, paying one host numpy/scipy power solve
per cell per round: O(cells x rounds) host round-trips.  This driver
runs all cells of a scenario in LOCKSTEP over rounds and routes power
control through the batched repro.phy solvers — cells sharing a
power-controller spec are stacked into one ``ChannelBatch`` and solved
in a single jitted device call, so the per-round host round-trips drop
to O(power-specs) = O(1) per round regardless of grid width.

Two further de-duplications the lockstep structure buys:

* power control never feeds back into training, so all cells of a
  quantizer share ONE training state — the jitted train step runs once
  per quantizer per round, not once per (quantizer x power) cell.
  Host ``run_grid`` gets the same trajectories by re-running identical
  RNG streams per power label; a cell that exhausts its latency budget
  snapshots the shared params at its stopping round.
* the stacked channel bundle is cached per power group and re-built
  only when some cell's realization object changed (Monte-Carlo
  redraws); with a fixed realization the device bundle uploads once.

Churn is handled by the solvers' mask argument (same sub-channel
semantics as the engine's host path — no power, no interference, no
straggler contribution for absent users).  Summaries gain a ``max_p``
column (the largest power coefficient any user was allocated across
the run — the CI sanity script asserts max_p <= 1, i.e. transmit
power <= p_max).

Numerics: the batched path solves in jax's default dtype (f32 unless
JAX_ENABLE_X64=1) while the host path is numpy f64, so latencies agree
to the documented parity tolerances (DESIGN.md section 7), not
bit-for-bit; tests/test_phy_driver.py pins the drift on a churn
scenario.

``replicates=R`` (DESIGN.md section 8) adds the Monte-Carlo replicate
axis: every (quantizer, power) cell runs R independent trajectories —
distinct minibatch/churn RNG streams, distinct channel realizations,
independently evolving quantizer state — and the lockstep structure is
preserved: still ONE jitted train call per quantizer per round (the
engine vmaps the replicate axis) and ONE power solve per power spec
per round (the R x cells uplink problems stack into one flat
ChannelBatch).  Summaries become across-replicate means with
``<metric>_ci95`` confidence half-widths; ``SweepResult.result`` holds
the per-replicate FLResult list.  ``replicates=1`` exercises the same
machinery and reproduces the unreplicated driver bit-for-bit on
training metrics (tests/test_mc_replicates.py).

Async scenarios (``Scenario.async_active``, DESIGN.md section 11) keep
the lockstep structure but NOT the shared-training-state dedup: the
event clock's arrival times depend on the power solve, so each
(quantizer, power) cell gets its own track.  The batched solve still
groups cells by power label — one device solve per power spec per
round — and after it each async cell runs the host event clock plus
ONE jitted aggregate dispatch (``complete_round_async``) before the
usual finish/accounting stage, whose latency burn-down then uses the
event-clock round duration instead of the slowest user.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple, Union

import jax
import numpy as np

from repro import obs as _obs
from repro.core.power import PowerController
from repro.phy import (batched_solver, bundle_from_realization_grid,
                       bundle_from_realizations)

from .engine import (ReplicatedRoundWork, ReplicatedRunState, RoundWork,
                     RunState, UplinkSolution, VectorizedFLEngine)
from .metrics import summarize_replicates
from .scenarios import Scenario, build_problem
from .sweep import (PowerSpec, QuantSpec, SweepCell, SweepResult,
                    _make_engine, _make_power, _resolve_scenario,
                    _to_result)


@dataclasses.dataclass
class _Track:
    """One quantizer's engine + its shared training state."""
    engine: VectorizedFLEngine
    state: RunState
    cells: List["_Cell"] = dataclasses.field(default_factory=list)

    @property
    def alive(self) -> bool:
        return any(c.alive for c in self.cells)


@dataclasses.dataclass
class _Cell:
    """One (quantizer, power) grid cell: per-cell latency accounting
    over the track's shared training trajectory."""
    track: _Track
    power: Optional[PowerController]
    qlabel: str
    plabel: str
    acct: RunState                 # logs / cum_latency / params snapshot
    alive: bool = True
    max_p: float = 0.0


@dataclasses.dataclass
class _ReplTrack:
    """One quantizer's engine + its shared R-replicate training state."""
    engine: VectorizedFLEngine
    state: ReplicatedRunState
    cells: List["_ReplCell"] = dataclasses.field(default_factory=list)

    @property
    def alive(self) -> bool:
        return any(c.alive.any() for c in self.cells)


@dataclasses.dataclass
class _ReplCell:
    """One (quantizer, power) cell with per-replicate accounting.

    The track's R trajectories are shared by all its cells; each cell
    keeps an [R] alive mask (a replicate stops logging once ITS latency
    budget is spent), per-replicate logs/cum-latency, and a params
    snapshot taken at each replicate's own stopping round.
    """
    track: _ReplTrack
    power: Optional[PowerController]
    qlabel: str
    plabel: str
    logs: List[List]               # [R] lists of RoundLog
    cum_latency: np.ndarray        # [R] float64
    alive: np.ndarray              # [R] bool
    rounds_done: np.ndarray        # [R] int
    params: List[object]           # [R] per-replicate final params
    max_p: float = 0.0


_BundleCache = Dict[str, Tuple[List[object], object]]


def _emit_solve_event(plabel: str, sol, mask: np.ndarray,
                      stragglers: np.ndarray) -> None:
    """Host-side ``phy.solve`` diagnostics for one power group's batched
    solve: user-rate percentiles over active users, straggler spread,
    and every per-cell solver info key (iteration counts, convergence
    flags, safeguard activations) reduced to mean/max."""
    rates = np.asarray(sol.rates, np.float64)
    act = rates[np.asarray(mask) > 0]
    fields: Dict[str, object] = {
        "power": plabel, "cells": int(rates.shape[0]),
        "straggler_s_max": float(np.max(stragglers)),
        "straggler_s_min": float(np.min(stragglers)),
    }
    if act.size:
        fields["rate_min"] = float(np.min(act))
        fields["rate_median"] = float(np.median(act))
        fields["rate_p95"] = float(np.percentile(act, 95.0))
    for k, v in sol.info.items():
        a = np.asarray(v)
        if a.ndim <= 1 and (np.issubdtype(a.dtype, np.number)
                            or a.dtype == np.bool_):
            fields[f"{k}_mean"] = float(np.mean(a))
            fields[f"{k}_max"] = float(np.max(a))
    _obs.record("phy.solve", **fields)


def _poison_bundle(cb):
    """The channel-estimate corruption fault: NaN out the cached device
    bundle's direct-link coefficients — the symptom `resilient_
    batched_solve` detects (non-finite solution rows) and recovers from
    by rebuilding the bundle from the retained realizations."""
    import jax.numpy as jnp

    return dataclasses.replace(cb, A_bar=cb.A_bar * jnp.float32(np.nan))


def _solve_round_batched(cells: List[_Cell], works: List[RoundWork],
                         cache: _BundleCache, t: int = 0,
                         resilience=None
                         ) -> Tuple[List[UplinkSolution], np.ndarray]:
    """One batched device solve per distinct power spec; returns one
    :class:`UplinkSolution` per cell — straggler latency plus per-user
    completion times [K] (zeros without a channel — the async event
    clock's input) for this round — and the per-cell count of power
    fallback stages consumed (all-zero without a resilience config)."""
    K0 = cells[0].track.engine.K if cells else 0
    uplinks = [0.0] * len(cells)
    per_user = [np.zeros(K0) for _ in cells]
    fb_counts = np.zeros(len(cells), np.int64)
    # group cells by power label (one spec per label within a grid)
    groups: Dict[str, List[int]] = {}
    for i, cell in enumerate(cells):
        if cell.power is None or cell.track.state.chan is None:
            continue
        groups.setdefault(cell.plabel, []).append(i)
    for plabel, idx in groups.items():
        chans = [cells[i].track.state.chan for i in idx]
        # cache holds the realization objects themselves (not ids —
        # GC id reuse across Monte-Carlo redraws would alias), so a
        # fixed realization uploads the device bundle exactly once
        hit = cache.get(plabel)
        if (hit is None or len(hit[0]) != len(chans)
                or any(a is not b for a, b in zip(hit[0], chans))):
            cache[plabel] = (chans, bundle_from_realizations(chans))
        cb = cache[plabel][1]
        K = chans[0].cfg.K
        bits = np.ones((len(idx), K))
        mask = np.zeros((len(idx), K))
        for row, i in enumerate(idx):
            mask[row] = works[i].active
            bits[row] = np.where(works[i].active > 0,
                                 np.maximum(works[i].bits_np, 1.0), 1.0)
        if resilience is not None:
            from repro.resilience.fallback import resilient_batched_solve

            if resilience.faults.channel_corrupt(t):
                cb = _poison_bundle(cb)
                cache[plabel] = (cache[plabel][0], cb)
            sol, fb, rebuilt = resilient_batched_solve(
                cells[idx[0]].power, cb, bits, mask,
                config=resilience, t=t, obs_tag=plabel,
                rebuild=lambda ch=chans: bundle_from_realizations(ch))
            if rebuilt is not None:
                cache[plabel] = (cache[plabel][0], rebuilt)
            for row, i in enumerate(idx):
                fb_counts[i] = fb[row]
        else:
            sol = batched_solver(cells[idx[0]].power)(cb, bits,
                                                      mask=mask)
        stragglers = np.asarray(sol.straggler_latency, np.float64)
        latencies = np.asarray(sol.latencies, np.float64)
        p_max_round = np.asarray(np.max(sol.p, axis=-1), np.float64)
        if _obs.enabled():
            _emit_solve_event(plabel, sol, mask, stragglers)
        for row, i in enumerate(idx):
            uplinks[i] = float(stragglers[row])
            per_user[i] = latencies[row]
            cells[i].max_p = max(cells[i].max_p, float(p_max_round[row]))
    return ([UplinkSolution(u, pu)
             for u, pu in zip(uplinks, per_user)], fb_counts)


def _run_scenario_lockstep(scn: Scenario, tracks: List[_Track],
                           verbose: bool, resilience=None,
                           ckpt=None) -> int:
    """Returns the resumed-from round frontier (0 for a fresh run)."""
    cache: _BundleCache = {}
    t0 = ckpt.restore_round(scn, tracks) if ckpt is not None else 0
    for t in range(t0 + 1, scn.T + 1):
        live_tracks = [tr for tr in tracks if tr.alive]
        if not live_tracks:
            break
        with _obs.round_scope(t):
            # ONE jitted training step per quantizer, shared by cells
            track_work = {}
            with _obs.scope("train_round"):
                for tr in live_tracks:
                    with _obs.context(quantizer=tr.cells[0].qlabel):
                        track_work[id(tr)] = tr.engine.train_round(
                            tr.state, t)
                        if _obs.enabled():
                            # deliver this track's jit taps under its
                            # quantizer tag (and time real compute)
                            jax.block_until_ready(tr.state.params)
            live = [c for tr in live_tracks for c in tr.cells
                    if c.alive]
            works = [track_work[id(c.track)] for c in live]
            with _obs.scope("solve_uplink"):
                sols, fallbacks = _solve_round_batched(
                    live, works, cache, t=t, resilience=resilience)
            with _obs.scope("finish_round"):
                for cell, work, (uplink, pu), fb in zip(
                        live, works, sols, fallbacks):
                    eng = cell.track.engine
                    info = None
                    with _obs.context(quantizer=cell.qlabel,
                                      power=cell.plabel):
                        if eng.engine_cfg.async_active:
                            # async tracks are per-(quantizer, power)
                            # cell (run_grid_batched), so completing on
                            # the TRACK's training state is exact
                            info = eng.complete_round_async(
                                cell.track.state, work, pu)
                        # accounting sees the shared trajectory's
                        # current params (snapshotted here, so a
                        # budget-stopped cell keeps the params of ITS
                        # final round even as the track trains on)
                        cell.acct.params = cell.track.state.params
                        cell.alive = eng.finish_round(
                            cell.acct, work, uplink, verbose=verbose,
                            async_info=info, per_user_s=pu,
                            power_fallbacks=int(fb))
        if ckpt is not None and t % ckpt.every == 0:
            ckpt.save_round(scn, tracks, t)
    return t0


def _solve_round_replicated(cells: List[_ReplCell],
                            works: List[ReplicatedRoundWork],
                            cache: _BundleCache, R: int, t: int = 0,
                            resilience=None
                            ) -> Tuple[np.ndarray, np.ndarray,
                                       np.ndarray]:
    """One batched device solve per distinct power spec over the
    flattened R x cells axis; returns per-(cell, replicate) straggler
    latencies [n_cells, R], per-user completion times [n_cells, R, K]
    and fallback-stage counts [n_cells, R] (zeros without a resilience
    config)."""
    uplinks = np.zeros((len(cells), R))
    K0 = cells[0].track.engine.K if cells else 0
    per_user = np.zeros((len(cells), R, K0))
    fb_counts = np.zeros((len(cells), R), np.int64)
    groups: Dict[str, List[int]] = {}
    for i, cell in enumerate(cells):
        if cell.power is None or cell.track.state.chans[0] is None:
            continue
        groups.setdefault(cell.plabel, []).append(i)
    for plabel, idx in groups.items():
        # row i * R + r of the flat bundle is (cell idx[i], replicate r)
        grid = [cells[i].track.state.chans for i in idx]
        flat = [chan for row in grid for chan in row]
        hit = cache.get(plabel)
        if (hit is None or len(hit[0]) != len(flat)
                or any(a is not b for a, b in zip(hit[0], flat))):
            cache[plabel] = (flat, bundle_from_realization_grid(grid))
        cb = cache[plabel][1]
        K = flat[0].cfg.K
        bits = np.ones((len(idx) * R, K))
        mask = np.zeros((len(idx) * R, K))
        for row, i in enumerate(idx):
            w = works[i]
            mask[row * R:(row + 1) * R] = w.active
            bits[row * R:(row + 1) * R] = np.where(
                w.active > 0, np.maximum(w.bits_np, 1.0), 1.0)
        if resilience is not None:
            from repro.resilience.fallback import resilient_batched_solve

            if resilience.faults.channel_corrupt(t):
                cb = _poison_bundle(cb)
                cache[plabel] = (cache[plabel][0], cb)
            sol, fb, rebuilt = resilient_batched_solve(
                cells[idx[0]].power, cb, bits, mask,
                config=resilience, t=t, obs_tag=plabel,
                rebuild=lambda g=grid: bundle_from_realization_grid(g))
            if rebuilt is not None:
                cache[plabel] = (cache[plabel][0], rebuilt)
            fb = np.asarray(fb, np.int64).reshape(len(idx), R)
            for row, i in enumerate(idx):
                fb_counts[i] = fb[row]
        else:
            sol = batched_solver(cells[idx[0]].power)(cb, bits,
                                                      mask=mask)
        stragglers = np.asarray(sol.straggler_latency,
                                np.float64).reshape(len(idx), R)
        latencies = np.asarray(sol.latencies,
                               np.float64).reshape(len(idx), R, K)
        if _obs.enabled():
            _emit_solve_event(plabel, sol, mask, stragglers)
        p_max_round = np.asarray(np.max(sol.p, axis=-1),
                                 np.float64).reshape(len(idx), R)
        for row, i in enumerate(idx):
            uplinks[i] = stragglers[row]
            per_user[i] = latencies[row]
            # max_p only over replicates still accounting (alive);
            # dead replicates' rows ride along for shape stability
            if cells[i].alive.any():
                cells[i].max_p = max(
                    cells[i].max_p,
                    float(np.max(p_max_round[row][cells[i].alive])))
    return uplinks, per_user, fb_counts


def _run_scenario_lockstep_replicated(scn: Scenario,
                                      tracks: List[_ReplTrack], R: int,
                                      verbose: bool, resilience=None,
                                      ckpt=None) -> int:
    """Returns the resumed-from round frontier (0 for a fresh run)."""
    cache: _BundleCache = {}
    t0 = ckpt.restore_round(scn, tracks) if ckpt is not None else 0
    for t in range(t0 + 1, scn.T + 1):
        live_tracks = [tr for tr in tracks if tr.alive]
        if not live_tracks:
            break
        with _obs.round_scope(t):
            # ONE jitted training step per quantizer, all R replicates
            track_work = {}
            with _obs.scope("train_round"):
                for tr in live_tracks:
                    with _obs.context(quantizer=tr.cells[0].qlabel):
                        track_work[id(tr)] = \
                            tr.engine.train_round_replicated(tr.state, t)
                        if _obs.enabled():
                            jax.block_until_ready(tr.state.params)
            live = [c for tr in live_tracks for c in tr.cells
                    if c.alive.any()]
            works = [track_work[id(c.track)] for c in live]
            with _obs.scope("solve_uplink"):
                uplinks, per_user, fallbacks = _solve_round_replicated(
                    live, works, cache, R, t=t, resilience=resilience)
            # async cells aggregate BEFORE eval (sync cells aggregated
            # inside the train step, so the eval ordering matches)
            infos: List[Optional[object]] = [None] * len(live)
            for i, (cell, work) in enumerate(zip(live, works)):
                eng = cell.track.engine
                if eng.engine_cfg.async_active:
                    with _obs.scope("complete_async"), \
                         _obs.context(quantizer=cell.qlabel,
                                      power=cell.plabel):
                        infos[i] = eng.complete_round_replicated_async(
                            cell.track.state, work, per_user[i])
            # per-replicate accuracy, once per track on eval rounds —
            # only for replicates some cell still accounts (a replicate
            # dead in EVERY cell of the track is never logged again)
            track_acc: Dict[int, Optional[np.ndarray]] = {}
            with _obs.scope("eval"):
                for tr in live_tracks:
                    track_acc[id(tr)] = (
                        tr.engine.eval_accuracy_replicated(
                            tr.state,
                            alive=np.logical_or.reduce(
                                [c.alive for c in tr.cells]))
                        if tr.engine.eval_due(t) else None)
            with _obs.scope("finish_round"):
                for cell, work, uplink, pu, fb, info in zip(
                        live, works, uplinks, per_user, fallbacks,
                        infos):
                    _finish_replicated_cell(cell, work, uplink,
                                            track_acc, t, R, verbose,
                                            async_info=info,
                                            per_user=pu, fallbacks=fb)
        if ckpt is not None and t % ckpt.every == 0:
            ckpt.save_round(scn, tracks, t)
    for tr in tracks:
        for cell in tr.cells:
            for r in np.flatnonzero(cell.alive):
                cell.params[r] = tr.engine.replicate_params(
                    tr.state, int(r))
    return t0


def _finish_replicated_cell(cell: _ReplCell, work: ReplicatedRoundWork,
                            uplink: np.ndarray,
                            track_acc: Dict[int, Optional[np.ndarray]],
                            t: int, R: int, verbose: bool,
                            async_info=None,
                            per_user: Optional[np.ndarray] = None,
                            fallbacks: Optional[np.ndarray] = None
                            ) -> None:
    from repro.fl.loop import RoundLog

    from .engine import straggler_gap

    eng = cell.track.engine
    comp_lat = eng.comp_lat
    accs = track_acc[id(cell.track)]
    K = eng.K
    for r in np.flatnonzero(cell.alive):
        if async_info is not None:
            # async: the event clock's round duration burns the budget
            up = float(async_info.round_uplink_s[r])
            gap = float(async_info.straggler_gap_s[r])
            eff = float(async_info.effective_participation[r])
            stale = float(async_info.mean_staleness[r])
            dropped = int(async_info.dropped_stale[r]
                          + async_info.dropped_churn[r])
        else:
            up = float(uplink[r])
            gap = 0.0 if per_user is None else straggler_gap(
                per_user[r], work.active[r])
            eff = float(np.sum(work.active[r] > 0)) / K
            stale, dropped = 0.0, 0
        cell.cum_latency[r] += up + comp_lat
        acc = None if accs is None else float(accs[r])
        quarantined = (int(work.quarantined[r])
                       if getattr(work, "quarantined", None) is not None
                       else 0)
        cell.logs[r].append(RoundLog(
            t, work.bits_np[r], up, comp_lat,
            float(cell.cum_latency[r]), float(work.mean_s[r]),
            acc, straggler_gap_s=gap, mean_staleness=stale,
            effective_participation=eff, dropped_uploads=dropped,
            quarantined_users=quarantined,
            power_fallbacks=(int(fallbacks[r])
                             if fallbacks is not None else 0)))
        cell.rounds_done[r] = t
        if eng.budget_spent(cell.cum_latency[r]):
            cell.alive[r] = False
            # budget exhausted: snapshot THIS replicate's
            # params at its final round while the track trains on
            cell.params[r] = eng.replicate_params(
                cell.track.state, int(r))
    if _obs.enabled():
        budget = eng.fl.latency_budget_s
        cum = cell.cum_latency[cell.alive] if cell.alive.any() \
            else cell.cum_latency
        if async_info is not None:
            gap_mean = float(np.mean(async_info.straggler_gap_s))
        elif per_user is not None:
            gap_mean = float(np.mean(
                [straggler_gap(per_user[r], work.active[r])
                 for r in range(R)]))
        else:
            gap_mean = 0.0
        _obs.record(
            "engine.round", t=t, quantizer=cell.qlabel,
            power=cell.plabel, replicates=R,
            alive_replicates=int(np.sum(cell.alive)),
            acc=None if accs is None else float(np.nanmean(accs)),
            bits_mean=float(work.bits_np.mean()),
            uplink_s=float(np.mean(uplink)),
            cum_latency_s=float(np.max(cell.cum_latency)),
            mean_s=float(np.mean(work.mean_s)),
            straggler_gap_s=gap_mean,
            budget_remaining_s=None if budget is None
            else float(budget - np.min(cum)))
    if verbose and accs is not None:
        # dead replicates carry NaN — average the live ones
        print(f"[round {t:4d}] {cell.qlabel}/{cell.plabel} "
              f"acc={np.nanmean(accs):.4f}±"
              f"{np.nanstd(accs):.4f} (R={R})")


def _to_replicated_result(scn: Scenario, cell: _ReplCell) -> SweepResult:
    from repro.fl.loop import FLResult

    results = [FLResult(params=cell.params[r], logs=cell.logs[r],
                        rounds_completed=int(cell.rounds_done[r]))
               for r in range(len(cell.logs))]
    summary = summarize_replicates([res.logs for res in results])
    summary["max_p"] = cell.max_p
    return SweepResult(cell=SweepCell(scn, cell.qlabel, cell.plabel),
                       result=results, summary=summary)


def run_grid_batched(scenarios: List[Union[str, Scenario]],
                     quantizers: Mapping[str, QuantSpec],
                     powers: Optional[Mapping[str, PowerSpec]] = None,
                     quick: bool = True, out_csv: Optional[str] = None,
                     latency_budget_s: Optional[float] = None,
                     verbose: bool = False, mesh=None,
                     replicates: Optional[int] = None,
                     resilience=None,
                     checkpoint_dir: Optional[str] = None,
                     checkpoint_every: int = 1
                     ) -> List[SweepResult]:
    """``run_grid`` semantics on the batched phy path.

    Same grid, same summaries (plus ``max_p``); within a scenario all
    cells advance round-by-round together and every round's power
    problems are solved in one jitted call per power spec.

    ``replicates=R`` (int >= 1) switches a scenario to the Monte-Carlo
    replicate axis: R independent trajectories per cell, still one
    train call per quantizer and one power solve per power spec per
    round; summaries gain mean/ci95 columns and ``SweepResult.result``
    becomes the per-replicate FLResult list.  ``replicates=None``
    (default) keeps the unreplicated driver unless the scenario itself
    declares ``Scenario.replicates > 1``.

    ``resilience`` (a :class:`repro.resilience.ResilienceConfig`) arms
    the fault-injection + detection + recovery layer (DESIGN.md §14):
    engines gain jit-traced payload guards, power solves route through
    the bounded fallback chain, and detect/recover actions surface as
    the ``quarantined_users`` / ``power_fallbacks`` metric columns.
    ``ResilienceConfig.none()`` reproduces the unarmed driver
    bit-for-bit (tests/test_resilience.py).

    ``checkpoint_dir`` makes the sweep preemption-safe: round-granular
    state snapshots land there every ``checkpoint_every`` rounds, and a
    re-run with the same directory skips finished scenarios and resumes
    interrupted ones from the last completed round frontier —
    ``resumed_from_round`` records where a resumed scenario's cells
    picked up.
    """
    from .metrics import write_metrics_csv

    if replicates is not None and replicates < 1:
        raise ValueError(f"replicates must be >= 1, got {replicates}")
    powers = powers if powers is not None else {"none": None}
    ckpt = None
    if checkpoint_dir is not None:
        from repro.resilience import SweepCheckpointer
        ckpt = SweepCheckpointer(checkpoint_dir, resilience=resilience,
                                 every=checkpoint_every)
    results: List[SweepResult] = []
    for scenario in scenarios:
        scn = _resolve_scenario(scenario, quick, latency_budget_s)
        with _obs.context(scenario=scn.name):
            n_before = len(results)
            R = replicates if replicates is not None \
                else (scn.replicates if scn.replicates > 1 else None)
            expected = len(quantizers) * len(powers)
            if ckpt is not None:
                done = ckpt.completed_rows(scn.name, expected)
                if done is not None:
                    # scenario finished in an earlier run: rebuild its
                    # summary rows from the checkpoint ledger (no
                    # FLResult — the params were not retained)
                    for row in done:
                        results.append(SweepResult(
                            cell=SweepCell(scn, row["quantizer"],
                                           row["power"]),
                            result=None,
                            summary={k: v for k, v in row.items()
                                     if k not in ("scenario",
                                                  "quantizer",
                                                  "power")}))
                    continue
            problem = build_problem(scn)
            chan = problem[4]
            # sync cells share one training state per quantizer (power
            # never feeds back into training); async arrival times DO
            # feed back, so async scenarios build one track per
            # (quantizer, power) cell.  The batched solve still groups
            # by power label, so it stays one device solve per power
            # spec per round either way.
            pgroups = ([[item] for item in powers.items()]
                       if scn.async_active else [list(powers.items())])
            if R is not None:
                tracks_r: List[_ReplTrack] = []
                for qlabel, qspec in quantizers.items():
                    for group in pgroups:
                        engine = _make_engine(scn, problem, qspec, None,
                                              mesh=mesh,
                                              resilience=resilience)
                        track = _ReplTrack(
                            engine=engine,
                            state=engine.start_replicated_run(R))
                        for plabel, pspec in group:
                            pc = _make_power(pspec)
                            track.cells.append(_ReplCell(
                                track=track,
                                power=pc if chan is not None else None,
                                qlabel=qlabel, plabel=plabel,
                                logs=[[] for _ in range(R)],
                                cum_latency=np.zeros(R),
                                alive=np.ones(R, dtype=bool),
                                rounds_done=np.zeros(R, dtype=np.int64),
                                params=[None] * R))
                        tracks_r.append(track)
                t0 = _run_scenario_lockstep_replicated(
                    scn, tracks_r, R, verbose, resilience=resilience,
                    ckpt=ckpt)
                for track in tracks_r:
                    for cell in track.cells:
                        results.append(_to_replicated_result(scn, cell))
            else:
                tracks: List[_Track] = []
                for qlabel, qspec in quantizers.items():
                    for group in pgroups:
                        engine = _make_engine(scn, problem, qspec, None,
                                              mesh=mesh,
                                              resilience=resilience)
                        track = _Track(engine=engine,
                                       state=engine.start_run())
                        for plabel, pspec in group:
                            pc = _make_power(pspec)
                            acct = dataclasses.replace(
                                track.state, logs=[], cum_latency=0.0,
                                rounds_done=0)
                            track.cells.append(_Cell(
                                track=track,
                                power=pc if chan is not None else None,
                                qlabel=qlabel, plabel=plabel,
                                acct=acct))
                        tracks.append(track)
                t0 = _run_scenario_lockstep(scn, tracks, verbose,
                                            resilience=resilience,
                                            ckpt=ckpt)
                for track in tracks:
                    for cell in track.cells:
                        res = _to_result(scn, track.engine,
                                         track.engine.result(cell.acct),
                                         (cell.qlabel, cell.plabel))
                        res.summary["max_p"] = cell.max_p
                        results.append(res)
            if t0 > 0:
                for res in results[n_before:]:
                    res.summary["resumed_from_round"] = float(t0)
            if ckpt is not None:
                ckpt.mark_scenario_done(
                    scn.name, [r.row() for r in results[n_before:]])
            if _obs.enabled():
                for res in results[n_before:]:
                    _obs.record(
                        "sweep.cell",
                        quantizer=res.cell.quantizer_label,
                        power=res.cell.power_label,
                        **{k: v for k, v in res.summary.items()
                           if isinstance(v, (int, float))})
    if out_csv:
        write_metrics_csv([r.row() for r in results], out_csv)
    return results


__all__ = ["run_grid_batched"]
