"""Vectorized FL-over-CFmMIMO engine — all K users in one dispatch.

The legacy loop (repro.fl.loop.run_fl_sequential) trains users one at a
time: per round it pays K jit dispatches for the local AdaGrad runs
plus K eager op-by-op quantizer calls, so wall-clock at the paper's
K=20/40 is dominated by dispatch overhead, not compute.  This engine
stacks the per-user minibatches to [K, L, b, ...] and runs the local
training of ALL users as one vmapped, jit-compiled step, followed by
one batched (vmapped) quantizer call on the stacked [K, d] deltas.

Execution modes (EngineConfig):

* exact (``fused=False``, default — what run_fl delegates to): the K
  local AdaGrad runs + delta flattening are a single jit dispatch;
  quantization and the rho-weighted aggregation then replay the
  sequential loop's eager op-for-op arithmetic in the same order.
  Round logs (params, bits, latency, accuracy) reproduce
  run_fl_sequential BIT-FOR-BIT at fixed seed (asserted by
  tests/test_sim_engine.py).  Fusing quantization into the same XLA
  graph would contract mul+add chains into FMAs and drift from the
  eager reference by 1 ulp per op — measured, and why this mode keeps
  quantize/aggregate eager.
* fused (``fused=True`` — what the scenario sweeps run): train,
  batched quantize, aggregation and the model update compile into ONE
  jit step per round.  Fastest path; equals the exact mode to float32
  roundoff (cross-op FMA contraction), not bit-for-bit.
* ``aggregation="signplane"`` (implies fused) — the fused step routes
  the low-resolution plane of the mixed-resolution scheme through the
  Pallas wire-format kernels: every user's delta sign plane is
  bit-packed with ``signpack`` ([W,128] f32 -> [W,4] uint32) and the
  rho*dw_q/2-weighted multi-user reduction runs in
  ``sign_dequant_reduce`` — the packed uint32 planes a real multi-peer
  aggregation would move — plus a dense correction on the (sparse)
  high-resolution support.  Exercises the wire format end-to-end
  instead of only in unit tests.
* ``aggregation="wire"`` (implies fused) — the full fused
  quantize-to-wire path (kernels/mixed_res.py, DESIGN.md section 9):
  the per-user quantization reductions, the packed sign/hi/code wire
  planes and the rho-weighted multi-user dequantize+reduce all run in
  the streaming mixed-resolution kernel suite, and the dense per-user
  reconstructions are never materialized.  Payload bits and the aux
  diagnostics replay the reference accounting exactly; the aggregated
  update agrees with the fused dense path to a documented ulp bound.

Beyond the paper's fixed setting the engine simulates per-round user
churn (partial participation with re-normalized aggregation weights and
frozen quantizer state for absent users) and Monte-Carlo channel
redraws (fresh large-scale realization every ``redraw_channel_every``
rounds) — see repro.sim.scenarios for the named workloads.

Replicated mode (the Monte-Carlo replicate axis, DESIGN.md section 8):
``start_replicated_run(R)`` / ``train_round_replicated`` run R
independent FL trajectories of the SAME problem — distinct minibatch
RNG streams, distinct participation draws, distinct channel
realizations, independently evolving quantizer states — with the whole
per-round device step vmapped over a leading R axis, so one jitted
dispatch per round trains all R trajectories.  R = 1 routes through
the IDENTICAL compiled step as the unreplicated path (no vmap), which
is what makes the replicate-parity suite's bit-for-bit claim possible.

Asynchronous mode (``EngineConfig(async_mode=True, staleness=...)``,
DESIGN.md section 11): per-user upload-completion times from the power
solve become a scheduling fact instead of a latency footnote.  Each
round the server waits only until a deadline (fixed seconds or a
quantile of the pending completion times), aggregates the uploads that
arrived with staleness weights ``rho_j (1+staleness_j)^-alpha``
renormalized into a convex combination, and parks the stragglers'
payloads in a bounded-staleness buffer (at most one in-flight upload
per user; dropped once ``staleness > max_staleness`` or when the user
churns out mid-upload).  The per-round device work stays two jitted
dispatches — one train+quantize call producing the fresh payloads
(dense [K, d] recons or packed MixedResWire planes) and one
aggregate+buffer-shuffle call — so the replicate axis and the fused
Pallas wire path keep working unchanged.  The host event clock between
them is pure numpy (``advance_async_clock``).

Public API / invariants:

* ``VectorizedFLEngine(...).run()`` — one-call driver; or the
  round-stepping quartet ``start_run`` / ``train_round`` /
  ``solve_uplink_host`` (returns an :class:`UplinkSolution`; the
  ``_detailed`` spelling is a deprecated alias) / ``finish_round``
  (async inserts ``complete_round_async`` between solve and finish —
  aggregation happens there, never in ``finish_round``).

Streaming cohorts (``EngineConfig(wire=WirePath(cohort_size=C))``,
DESIGN.md section 12): the fused packed-plane step scans the K users
in cohorts of C — each scan iteration trains C users, encodes their
packed wire planes and folds the weighted dequant-reduce into a
carried [d] accumulator, so the dense [K, d] gradient matrix never
exists at any fan-in and device residency scales with C, not K.
``cohort_size=None`` keeps today's fully vectorized step bit-for-bit.
``WirePath(clusters=N)`` adds the two-level hierarchy: contiguous
AP-cluster user groups aggregate into partial [d] planes on device
(only one cluster's minibatches resident at a time), combined
host-ordered before a single param update.
* Replicated: ``start_replicated_run(R)`` / ``train_round_replicated``
  (+ ``complete_round_replicated_async``); R=1 is bit-for-bit the
  unreplicated path (same compiled step, squeezed).
* ``async_mode=True`` with a sync StalenessConfig (no deadline — the
  "alpha=0, infinite deadline" reduction) runs EXACTLY the lockstep
  code path: bit-for-bit with async_mode=False by construction
  (tests/test_async_engine.py pins it).
* Sync mode never reads the async fields; all pre-async call sites
  keep their behavior bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import (ChannelRealization, computation_latency,
                                make_channel)
from repro.core.power.base import PowerController
from repro.core.quantize import Quantizer
from repro.core.quantize.base import flatten_pytree, unflatten_pytree
from repro.core.quantize.layer_budget import segmented_quantize
from repro.data.federated import user_fractions, validate_shards
from repro.data.synthetic import ImageDataset
# the mixed-resolution signplane aggregation identity (packed 1-bit
# reduce + dense correction on the top-k support) has ONE definition,
# shared with repro.dist's cross-replica aggregation
from repro.dist.compressor import \
    signplane_weighted_aggregate as _signplane_aggregate
from repro.kernels import WirePath, check_packed_dim, from_aggregation
from repro.kernels.ops import (H_DBAR, H_DWQ, H_INF, MixedResWire,
                               mixed_res_encode, mixed_res_wire_reduce,
                               segmented_wire_aggregate)
from repro.kernels.ops import mixed_res_wire_aggregate as _wire_aggregate
from repro.resilience import guards as _rg
from repro import obs as _obs


@dataclasses.dataclass(frozen=True)
class StalenessConfig:
    """Async round-deadline + staleness-weighting policy.

    The server closes a round at ``min(deadline, time all pending
    uploads complete)`` where the deadline is either ``deadline_s``
    (fixed seconds) or the ``deadline_quantile`` of this round's
    pending completion times (fresh uploads' solve latencies plus
    in-flight uploads' remaining times).  Exactly one of the two may
    be set; with BOTH unset the config is "sync" (infinite deadline:
    every round waits for its slowest upload — today's lockstep) and
    ``EngineConfig.async_active`` stays False even under
    ``async_mode=True``, which is the bit-for-bit sync reduction the
    parity test pins.

    Arrivals are averaged with weights ``rho_j (1+staleness_j)^-alpha``
    renormalized to a convex combination (``staleness_weights``);
    ``alpha=0`` weighs stale and fresh uploads alike.  A missed upload
    waits in the buffer at most ``max_staleness`` rounds
    (``max_staleness=0`` disables buffering: misses are dropped
    outright).
    """
    deadline_s: Optional[float] = None
    deadline_quantile: Optional[float] = None
    alpha: float = 0.0
    max_staleness: int = 2

    def __post_init__(self):
        if self.deadline_s is not None and self.deadline_quantile is not None:
            raise ValueError("set deadline_s OR deadline_quantile, not both")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")
        if self.deadline_quantile is not None and not (
                0.0 < self.deadline_quantile <= 1.0):
            raise ValueError("deadline_quantile must be in (0, 1], got "
                             f"{self.deadline_quantile}")
        if self.alpha < 0:
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")
        if self.max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {self.max_staleness}")

    @property
    def is_sync(self) -> bool:
        """No finite deadline configured — the lockstep reduction."""
        return (self.deadline_s is None or np.isinf(self.deadline_s)) \
            and self.deadline_quantile is None


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-level knobs beyond the paper's Algorithm 1."""
    # DEPRECATED spelling of the wire-path plane — "dense" |
    # "signplane" | "wire".  New call sites set ``wire=WirePath(...)``
    # instead; the legacy strings keep working through
    # repro.kernels.from_aggregation (DeprecationWarning).
    aggregation: str = "dense"
    # The unified wire-path spec (repro.kernels.WirePath): which plane
    # moves at the fan-in, which lowering runs it, and the streaming
    # knobs (cohort_size — scan the K users in cohorts so no [K, d]
    # buffer ever exists; clusters — two-level AP-cluster hierarchy).
    # None defers to the legacy ``aggregation`` string; setting BOTH a
    # non-default aggregation and wire is an error.
    wire: Optional[WirePath] = None
    # fused=False (exact mode): only the K local AdaGrad runs share one
    # jit dispatch; quantization and aggregation replay the sequential
    # loop's eager per-op arithmetic — BIT-FOR-BIT equal to
    # run_fl_sequential.  fused=True (production mode): train, batched
    # quantize, aggregate and model update compile into ONE jit step
    # per round; XLA's cross-op fusion (FMA contraction etc.) makes it
    # equal to the exact mode only to float32 roundoff.
    # aggregation="signplane" always runs fused.
    fused: bool = False
    # How the K users' local AdaGrad runs are batched inside the single
    # jitted step.  "map" (lax.map) compiles the per-user graph once and
    # loops it on-device — on CPU the per-user convs hit the fast
    # unbatched lowering (vmap turns them into grouped convs, measured
    # ~3x slower there).  "vmap" batches all users' convs into one
    # grouped launch — the right choice on TPU/GPU.  Both are bitwise
    # identical to the sequential per-user jit.
    local_batching: str = "map"      # "map" | "vmap"
    # How the Monte-Carlo replicate axis R is batched inside the single
    # jitted replicated step.  "vmap" batches all R trajectories' convs
    # together — right on TPU/GPU; on CPU it hits the same slow
    # grouped-conv lowering as local_batching="vmap", so "auto"
    # (default) picks "map" (lax.map: compile the per-replicate graph
    # once, loop it on-device — still ONE dispatch per round) on CPU
    # and "vmap" on accelerators.  aggregation="signplane"/"wire"
    # always run "map": the Pallas wire kernels expect their unbatched
    # windows.
    replicate_batching: str = "auto"  # "auto" | "map" | "vmap"
    participation: float = 1.0       # P(user active in a round) — churn
    redraw_channel_every: int = 0    # 0 = fixed realization (paper)
    channel_seed: int = 0            # base seed for Monte-Carlo redraws
    # Optional jax Mesh with a "data" axis: the user axis K of every
    # stacked array (minibatches, deltas, quantizer state) is laid over
    # it, so one engine step scales the K users across devices — the
    # sweep-layer counterpart of repro.dist's replica sharding.  None =
    # single-device (default); ignored with a warning unless the
    # data-axis size divides K evenly.
    mesh: Optional[object] = None
    # Round logging.  Every finished round is emitted to the active
    # repro.obs session (no-op without one); verbose=True additionally
    # prints the quickstart's per-eval-round console line (same as
    # run(verbose=True)), throttled to every log_every-th eval round.
    verbose: bool = False
    log_every: int = 1
    # Asynchronous rounds (DESIGN.md section 11): per-user upload
    # completion times govern aggregation.  async_mode=True with a
    # sync StalenessConfig (no deadline) runs the lockstep code path
    # unchanged — see async_active.
    async_mode: bool = False
    staleness: StalenessConfig = dataclasses.field(
        default_factory=StalenessConfig)
    # Optional repro.resilience.ResilienceConfig: threads seeded
    # per-round fault masks through the fused step and arms the
    # jit-safe quarantine guards (DESIGN.md §14).  None (default)
    # builds the exact pre-resilience step graphs; a config with
    # FaultPlan.none() injects nothing and is bit-for-bit with None
    # (tests/test_resilience.py parity battery).
    resilience: Optional[object] = None

    @property
    def effective_fused(self) -> bool:
        if self.wire is not None:
            return self.fused or self.wire.plane != "dense"
        return self.fused or self.aggregation in ("signplane", "wire")

    def wire_path(self) -> WirePath:
        """The resolved WirePath: ``wire`` when set, else the legacy
        ``aggregation`` string mapped through its deprecation shim
        (silently for the "dense" default)."""
        if self.wire is not None:
            if self.aggregation != "dense":
                raise ValueError(
                    "set EngineConfig.wire OR the legacy aggregation "
                    f"string, not both (wire={self.wire!r}, "
                    f"aggregation={self.aggregation!r})")
            return self.wire
        return from_aggregation(self.aggregation,
                                warn=self.aggregation != "dense")

    @property
    def async_active(self) -> bool:
        """True only when async machinery actually engages: async_mode
        AND a finite deadline.  ``async_mode=True`` with the default
        (sync) StalenessConfig reduces to today's lockstep engine
        bit-for-bit because this property gates EVERY async branch."""
        return self.async_mode and not self.staleness.is_sync


def _subchannel(chan: ChannelRealization, idx: np.ndarray
                ) -> ChannelRealization:
    """Restrict a realization to the active-user subset: inactive users
    neither transmit (no power allocated, no interference) nor count
    toward the straggler latency.

    The batched phy path (repro.phy solvers with a 0/1 ``mask``)
    implements these same semantics device-side; equivalence is pinned
    by tests/test_phy_parity.py and tests/test_phy_driver.py.
    """
    cfg = dataclasses.replace(chan.cfg, K=len(idx))
    return dataclasses.replace(
        chan, cfg=cfg, beta=chan.beta[:, idx], pilot=chan.pilot[idx],
        gamma=chan.gamma[:, idx], A_bar=chan.A_bar[idx],
        B_bar=chan.B_bar[idx], B_tilde=chan.B_tilde[np.ix_(idx, idx)],
        I_M=chan.I_M[idx])


# ------------------------------------------------- async event clock
def staleness_weights(rho: np.ndarray, staleness: np.ndarray,
                      arrived: np.ndarray, alpha: float) -> np.ndarray:
    """Normalized aggregation weights ``rho_j (1+s_j)^-alpha`` over the
    arrived set — a convex combination (non-negative, sums to 1 per
    leading-batch row) whenever any upload arrived, all-zero otherwise.

    rho: [K]; staleness/arrived: [..., K] (staleness in rounds, 0 for
    fresh uploads).  Pure numpy — the hypothesis property battery in
    tests/test_async_engine.py exercises it directly.
    """
    arr = np.asarray(arrived, bool)
    raw = (np.asarray(rho, np.float64)
           * (1.0 + np.asarray(staleness, np.float64)) ** (-float(alpha))
           * arr)
    tot = raw.sum(axis=-1, keepdims=True)
    return np.divide(raw, tot, out=np.zeros_like(raw), where=tot > 0)


def straggler_gap(per_user_s: np.ndarray, mask: np.ndarray) -> float:
    """Slowest-minus-median upload completion time over ``mask`` users
    — the round's straggler gap (0 when fewer than one uploader)."""
    lat = np.asarray(per_user_s, np.float64)[np.asarray(mask) > 0]
    if lat.size == 0:
        return 0.0
    return float(np.max(lat) - np.median(lat))


class AsyncClockStep(NamedTuple):
    """One ``advance_async_clock`` transition.  All arrays [B, K]
    unless noted; B is the replicate axis (1 unreplicated)."""
    round_s: np.ndarray            # [B] event-clock round duration
    arrived: np.ndarray            # bool — aggregated this round
    w_fresh: np.ndarray            # weights of arrived FRESH uploads
    w_buf: np.ndarray              # weights of arrived BUFFERED uploads
    move: np.ndarray               # fresh upload missed -> enters buffer
    keep: np.ndarray               # buffered upload missed -> stays
    in_flight: np.ndarray          # next round's busy mask (move|keep)
    remaining_s: np.ndarray        # next round's remaining upload time
    staleness: np.ndarray          # next round's buffer staleness
    arrived_staleness: np.ndarray  # staleness of each arrival (0 fresh)
    dropped_stale: np.ndarray      # [B] uploads dropped: staleness bound
    dropped_churn: np.ndarray      # [B] uploads dropped: user churned out
    straggler_gap_s: np.ndarray    # [B] max - median pending completion


def advance_async_clock(in_flight: np.ndarray, remaining_s: np.ndarray,
                        staleness: np.ndarray, ell: np.ndarray,
                        fresh: np.ndarray, participating: np.ndarray,
                        rho: np.ndarray, cfg: StalenessConfig
                        ) -> AsyncClockStep:
    """Pure host event-clock transition for one async round.

    Inputs are [B, K]: ``in_flight``/``remaining_s``/``staleness`` the
    buffer state, ``ell`` this round's per-user solve latencies (fresh
    uploads), ``fresh`` the fresh-uploader mask and ``participating``
    the churn mask.  Semantics:

    * an in-flight upload whose user churned out is dropped — a user
      who drops mid-upload must never be aggregated;
    * the round closes at ``min(deadline, max pending completion)`` —
      with every pending upload inside the deadline this equals the
      lockstep straggler latency;
    * arrivals (completion <= round_s) are weighted by
      ``staleness_weights``; misses enter/stay in the buffer with
      ``remaining_s`` decremented by the elapsed round and staleness
      bumped, dropped once ``staleness > cfg.max_staleness``.
    """
    part = np.asarray(participating) > 0
    fresh = np.asarray(fresh) > 0
    churn_drop = in_flight & ~part
    busy = in_flight & part
    cand = np.where(fresh, np.asarray(ell, np.float64), np.inf)
    cand = np.where(busy, remaining_s, cand)
    pending = fresh | busy
    B = cand.shape[0]
    round_s = np.zeros(B)
    gap = np.zeros(B)
    for b in range(B):
        pc = cand[b][pending[b]]
        if pc.size == 0:
            continue
        if cfg.deadline_s is not None:
            deadline = float(cfg.deadline_s)
        else:
            deadline = float(np.quantile(pc, cfg.deadline_quantile))
        # a server that saw every pending upload land early closes the
        # round then — deadline_s=inf therefore reduces to lockstep
        round_s[b] = min(deadline, float(pc.max()))
        gap[b] = float(pc.max() - np.median(pc))
    arrived = pending & (cand <= round_s[:, None])
    arr_stale = np.where(busy, staleness, 0)
    w = staleness_weights(rho, arr_stale, arrived, cfg.alpha)
    # misses: fresh ones enter the buffer at staleness 1 (dropped
    # outright when max_staleness == 0); buffered ones age one round
    miss_fresh = fresh & ~arrived
    miss_buf = busy & ~arrived
    stale_drop = miss_buf & (staleness + 1 > cfg.max_staleness)
    keep = miss_buf & ~stale_drop
    move = miss_fresh if cfg.max_staleness >= 1 \
        else np.zeros_like(miss_fresh)
    elapsed = round_s[:, None]
    return AsyncClockStep(
        round_s=round_s, arrived=arrived,
        w_fresh=w * (fresh & arrived), w_buf=w * (busy & arrived),
        move=move, keep=keep, in_flight=move | keep,
        remaining_s=np.where(move, cand - elapsed,
                             np.where(keep, remaining_s - elapsed, 0.0)),
        staleness=np.where(move, 1, np.where(keep, staleness + 1, 0)),
        arrived_staleness=np.where(arrived, arr_stale, 0),
        dropped_stale=(stale_drop | (miss_fresh & ~move)).sum(axis=-1),
        dropped_churn=churn_drop.sum(axis=-1),
        straggler_gap_s=gap)


@dataclasses.dataclass
class AsyncClock:
    """Mutable async buffer state threaded through a run.

    Host arrays are [B, K] (B = 1 unreplicated, else R); ``buffer``
    holds the parked device payloads — dense [(B,) K, d] recons or
    stacked MixedResWire planes — aligned slot-per-user (at most one
    in-flight upload per user).  ``payload`` stages the current
    round's fresh device payload between ``train_round`` and
    ``complete_round_async``."""
    in_flight: np.ndarray
    remaining_s: np.ndarray
    staleness: np.ndarray
    buffer: object
    payload: object = None
    uploads_started: int = 0
    arrived_total: int = 0
    dropped_stale: int = 0
    dropped_churn: int = 0


@dataclasses.dataclass
class AsyncRoundInfo:
    """Per-round async accounting (arrays [B]; B = 1 unreplicated)."""
    round_uplink_s: np.ndarray     # event-clock round duration
    n_arrived: np.ndarray          # arrivals aggregated this round
    mean_staleness: np.ndarray     # mean staleness over arrivals
    max_staleness_obs: np.ndarray  # max staleness over arrivals
    straggler_gap_s: np.ndarray    # max - median pending completion
    dropped_stale: np.ndarray
    dropped_churn: np.ndarray
    effective_participation: np.ndarray   # n_arrived / K
    in_flight_next: np.ndarray     # buffer occupancy entering next round


class UplinkSolution(NamedTuple):
    """Structured result of the uplink power solve (stage 3).

    A NamedTuple so the legacy ``straggler_s, per_user_s = solve...``
    unpacking keeps working; ``latencies`` is always populated ([K]
    per-user upload-completion times, 0 for absent users — the async
    event clock's input).  The batched driver's replicated variant
    carries [R, K]."""
    straggler_s: float
    latencies: np.ndarray


@dataclasses.dataclass
class RoundWork:
    """What one training round hands to the power-control stage.

    In async mode ``active`` is the FRESH-uploader mask (participating
    and not mid-upload — the users whose payloads this round's power
    solve carries) and ``participating`` the raw churn mask; in sync
    mode they coincide and ``participating`` stays None."""
    t: int
    bits_np: np.ndarray            # [K] payload bits; 0 for absent users
    active: np.ndarray             # [K] 0/1 participation mask
    mean_s: float                  # mean high-res fraction (active users)
    participating: Optional[np.ndarray] = None   # [K] churn mask (async)
    quarantined: int = 0           # users masked out by the guards


@dataclasses.dataclass
class ReplicatedRoundWork:
    """RoundWork with a leading Monte-Carlo replicate axis R."""
    t: int
    bits_np: np.ndarray            # [R, K] payload bits; 0 for absent users
    active: np.ndarray             # [R, K] 0/1 participation masks
    mean_s: np.ndarray             # [R] mean high-res fraction per replicate
    participating: Optional[np.ndarray] = None   # [R, K] churn masks (async)
    quarantined: Optional[np.ndarray] = None     # [R] guard-masked users


@dataclasses.dataclass
class RunState:
    """Mutable per-run state for the round-stepping API.

    ``run()`` drives it with the host power solve; the batched grid
    driver (repro.sim.phy_driver) steps many engines' states in
    lockstep and supplies uplink latencies from ONE batched phy solve
    per round.
    """
    params: object
    qstate: object
    chan: Optional[ChannelRealization]
    rng: np.random.Generator
    part_rng: np.random.Generator
    test_x: object
    test_y: object
    logs: List
    cum_latency: float = 0.0
    rounds_done: int = 0
    async_clock: Optional[AsyncClock] = None


@dataclasses.dataclass
class ReplicatedRunState:
    """Per-run state for R vmapped Monte-Carlo replicates.

    Device arrays carry a leading R axis (params/qstate pytrees);
    host-side RNG streams and channel realizations are per-replicate
    lists.  Latency accounting is NOT here — the replicated grid
    driver (repro.sim.phy_driver) owns it per (cell, replicate), since
    one training state serves many power cells.
    """
    params: object                          # [R]-stacked param pytree
    qstate: object                          # [R, K, ...] stacked (or None)
    chans: List[Optional[ChannelRealization]]   # length R
    rngs: List[np.random.Generator]             # minibatch streams
    part_rngs: List[np.random.Generator]        # churn streams
    test_x: object
    test_y: object
    rounds_done: int = 0
    async_clock: Optional[AsyncClock] = None

    @property
    def R(self) -> int:
        return len(self.rngs)


# RNG-stream folding for replicate r > 0 (replicate 0 keeps the
# unreplicated streams bit-for-bit — the parity contract):
# minibatches   default_rng((seed, _REPL_TAG, r))
# churn         default_rng((seed, 0x5EED, _REPL_TAG, r))
# channels      make_channel(seed = channel_seed + r * stride + t)
# The channel-seed stride keeps replicate streams disjoint from the
# unreplicated redraw seeds (channel_seed + t, t <= T << stride).
_REPL_TAG = 0x4D43                  # "MC"
_REPL_CHANNEL_SEED_STRIDE = 1 << 20

# ordinal for per-instance obs retrace-probe names: a grid builds one
# engine per quantizer and each one legitimately traces its step once,
# so probe counts must not aggregate across instances (a shared name
# would read as a retrace storm)
_ENGINE_ORDINAL = [0]


class VectorizedFLEngine:
    """Algorithm 1 with all K users vectorized into one step per round.

    Drop-in engine behind :func:`repro.fl.run_fl`; also the substrate
    for the scenario sweeps in repro.sim.sweep.  The wireless part
    (power control, closed-form rates) stays on the host exactly as in
    the sequential loop.
    """

    def __init__(self, dataset: ImageDataset, test: ImageDataset,
                 shards: List[np.ndarray], model,
                 quantizer: Quantizer, power: Optional[PowerController],
                 chan: Optional[ChannelRealization], fl,
                 engine: Optional[EngineConfig] = None):
        # ``model``: a repro.fl.ModelSpec or (the historical signature)
        # a PaperCNNConfig.  Local import: repro.fl imports us.
        from repro.fl.models import as_model_spec

        self.model_spec = as_model_spec(model)
        self.cnn_cfg = self.model_spec.config   # legacy attribute
        self.engine_cfg = engine or EngineConfig()
        # one resolved WirePath drives every plane/lowering/streaming
        # decision below; the legacy aggregation string warns here once
        wp = self.engine_cfg.wire_path()
        self.wire_path_spec = wp
        self._plane = wp.plane
        self._cohort = wp.cohort_size
        self._clusters = wp.clusters
        if self.engine_cfg.local_batching not in ("map", "vmap"):
            raise ValueError(
                f"unknown local_batching {self.engine_cfg.local_batching!r}")
        if self.engine_cfg.replicate_batching not in ("auto", "map",
                                                      "vmap"):
            raise ValueError(f"unknown replicate_batching "
                             f"{self.engine_cfg.replicate_batching!r}")
        if (self._plane in ("signplane", "packed")
                and quantizer.name != "mixed-resolution"):
            raise ValueError(
                f"the {self._plane} wire plane packs the "
                "mixed-resolution wire format; quantizer "
                f"{quantizer.name!r} has none")
        if self._plane == "packed" and quantizer.b > 16:
            raise ValueError(
                "the wire kernels store magnitude codes in <= 16 bits; "
                f"got b={quantizer.b}")
        if self.engine_cfg.async_active:
            if not self.engine_cfg.effective_fused:
                raise ValueError(
                    "async rounds split the fused step into train and "
                    "aggregate dispatches; configure "
                    "EngineConfig(fused=True)")
            if self._plane == "signplane":
                raise ValueError(
                    "async rounds buffer packed payloads; use the "
                    "'packed' plane (full wire format) or 'dense'")
            if wp.streaming:
                raise ValueError(
                    "async rounds buffer full-K payload slots; cohort "
                    "streaming (WirePath.cohort_size) is lockstep-only")
            if self.engine_cfg.mesh is not None:
                warnings.warn(
                    "EngineConfig.mesh user-axis sharding is not "
                    "supported in async mode; running unsharded",
                    stacklevel=2)
        if wp.streaming and self.engine_cfg.mesh is not None:
            warnings.warn(
                "EngineConfig.mesh user-axis sharding is not supported "
                "with cohort streaming; running unsharded", stacklevel=2)

        self.dataset, self.test = dataset, test
        self.shards = shards
        self.quantizer, self.power, self.chan, self.fl = \
            quantizer, power, chan, fl
        self.K = len(shards)
        validate_shards(shards)   # empty shard -> clear error, not take=0
        # uniform minibatch size so user batches stack to [K, L, b];
        # identical to the sequential loop whenever every shard holds at
        # least batch_size samples (the benchmarks' regime)
        self.take = min(fl.batch_size, min(len(s) for s in shards))
        if self.take < fl.batch_size:
            warnings.warn(
                f"smallest shard ({self.take} samples) < batch_size "
                f"({fl.batch_size}): the engine's uniform [K, L, b] "
                f"stacking trains EVERY user with batch {self.take} "
                "(the sequential loop clamps per user; run_fl falls "
                "back to it in this case)", stacklevel=2)
        self.rho = user_fractions(shards)

        self.params = self.model_spec.init(jax.random.PRNGKey(fl.seed))
        flat0, self.spec = flatten_pytree(self.params)
        self.d = int(flat0.size)
        if self._plane == "packed":
            # shared guard (repro.kernels.check_packed_dim): the f32
            # high-res count is exact only to 2**24 — fail at
            # construction, not mid-run in the jit
            check_packed_dim(self.d, where="the packed wire plane")
        self._segments = self._resolve_budget_segments(wp)
        self._resilience = self.engine_cfg.resilience
        if self._resilience is not None:
            if not self.engine_cfg.effective_fused:
                raise ValueError(
                    "the resilience guards trace into the fused round "
                    "step; configure EngineConfig(fused=True) (the "
                    "exact mode's eager sequential replay has no "
                    "guard insertion points)")
            if self._clusters > 1:
                raise ValueError(
                    "resilience guards are not supported with the "
                    "two-level cluster hierarchy (WirePath.clusters > "
                    "1); drop clusters or resilience")
        self.qstate = quantizer.init_batched_state(self.K, self.d)
        self.comp_lat = computation_latency(fl.L, fl.dataset_size_for_comp,
                                            self.K)
        _ENGINE_ORDINAL[0] += 1
        self._obs_name = f"engine{_ENGINE_ORDINAL[0]}[{quantizer.name}]"
        self._user_sharding, self._repl_sharding = self._user_shardings()
        if self.engine_cfg.effective_fused:
            self._train_flat = None
            self._fused_step_fn = self._build_fused_step_fn()
            self._fused_step = self._jit_fused_step(self._fused_step_fn)
        else:
            self._train_flat = self._build_train_flat()
            self._fused_step_fn = None
            self._fused_step = None
        # replicate-axis step cache: R -> jitted vmap of the fused step
        self._repl_step_cache = {}
        # async step cache: R (None = unreplicated) -> (train, agg)
        self._async_step_cache = {}
        if self._clusters > 1:
            # two-level hierarchy: per-cluster partial aggregates
            # (cohort scan over the cluster's users — jit retraces per
            # distinct cluster size) + one host-ordered combine and one
            # param-update dispatch
            self._cluster_step = jax.jit(
                _obs.retrace_probe(f"sim.cluster_step/{self._obs_name}")(
                    lambda p, xs, ys, w:
                    self._cohort_accumulate(p, xs, ys, w)))
            self._combine_partials = jax.jit(lambda a, b: a + b)
            self._apply_update = jax.jit(
                lambda p, u: jax.tree_util.tree_map(
                    lambda x, v: x + v, p,
                    unflatten_pytree(u, self.spec)))
            # bits accounting runs the SAME compiled _head_stats graph
            # as the flat fused step, so per-user payload bits stay
            # bitwise-equal across clusters=1 and clusters>1
            self._head_stats_jit = jax.jit(self._head_stats)

    # ------------------------------------------------------------ build
    def _resolve_budget_segments(self, wp: WirePath):
        """Resolve ``WirePath.budget`` against the model's params tree.

        Returns the static segment tuple for a non-uniform budget, or
        None — a uniform/absent budget keeps the pre-existing global
        path, which is the bit-for-bit parity contract (DESIGN.md §13).
        """
        budget = getattr(wp, "effective_budget", None)
        if budget is None:
            return None
        q = self.quantizer
        if q.name != "mixed-resolution":
            raise ValueError(
                "per-layer budgets re-parameterize the mixed-resolution "
                f"scheme per segment; quantizer {q.name!r} has no "
                "(lambda_, b) budget")
        if not self.engine_cfg.effective_fused:
            raise ValueError(
                "per-layer budgets run per-segment quantization inside "
                "the fused step; configure EngineConfig(fused=True) "
                "(the exact mode's eager sequential replay is global-"
                "budget by definition)")
        if self.engine_cfg.async_active:
            raise ValueError(
                "per-layer budgets are not supported in async mode yet; "
                "use LayerBudget.uniform() or sync rounds")
        segments = budget.segments_for(self.params, q.lambda_, q.b)
        if self._plane == "packed":
            for seg in segments:
                if seg.b > 16:
                    raise ValueError(
                        "the wire kernels store magnitude codes in <= 16 "
                        f"bits; budget group {seg.group!r} has b={seg.b}")
        return segments

    def _user_shardings(self):
        """(user-axis, replicated) NamedShardings when an engine mesh
        is configured — the K axis of stacked arrays goes over the
        mesh's data axis so one step runs the users device-parallel."""
        mesh = self.engine_cfg.mesh
        if mesh is None or self._cohort is not None:
            # cohort streaming scans the user axis on one device —
            # __init__ already warned if a mesh was also configured
            return None, None
        from jax.sharding import NamedSharding, PartitionSpec as P
        if "data" not in getattr(mesh, "shape", {}):
            warnings.warn("engine mesh has no 'data' axis; user-axis "
                          "sharding disabled", stacklevel=2)
            return None, None
        nd = mesh.shape["data"]
        if self.K % nd != 0:
            warnings.warn(
                f"data axis ({nd}) does not divide K={self.K} users "
                "evenly; user-axis sharding disabled", stacklevel=2)
            return None, None
        return (NamedSharding(mesh, P("data")),
                NamedSharding(mesh, P()))

    def _batched_local(self, params, xs, ys):
        """All stacked users' local AdaGrad runs -> [U, d] deltas
        (U = K vectorized, or one cohort C under streaming).  Traced
        inside the jitted step; batching per EngineConfig."""
        from repro.fl.loop import local_adagrad  # local: avoids cycle

        fl, U = self.fl, xs.shape[0]
        loss = self.model_spec.loss
        if self.engine_cfg.local_batching == "vmap":
            local = jax.vmap(
                lambda x, y: local_adagrad(params, x, y, fl.L, fl.alpha,
                                           loss)
            )(xs, ys)
        else:
            local = jax.lax.map(
                lambda xy: local_adagrad(params, xy[0], xy[1], fl.L,
                                         fl.alpha, loss),
                (xs, ys))
        delta = jax.tree_util.tree_map(lambda w, p: w - p, local, params)
        leaves = jax.tree_util.tree_flatten(delta)[0]
        return jnp.concatenate(
            [jnp.reshape(l, (U, -1)).astype(jnp.float32)
             for l in leaves], axis=1)                        # [U, d]

    # ------------------------------------------- cohort streaming path
    def _head_stats(self, head):
        """Per-user payload bits + aux diagnostics from stacked wire
        headers [U, 8] — the same arithmetic, in the same op order, as
        ``mixed_res_wire_aggregate`` (bitwise-equal bits accounting)."""
        q, d = self.quantizer, self.d
        inf = head[:, H_INF]
        dw_q = head[:, H_DWQ]
        dbar = head[:, H_DBAR]
        s = dbar / d
        bits = d * (q.b * s + 1.0 - s) + 32.0
        bits = jnp.where(inf > 0, bits, float(d) + 32.0)
        aux = {"s": s, "dbar": dbar.astype(jnp.int32), "r": inf - dw_q,
               "dw_q": dw_q, "inf": inf}
        return bits, aux

    def _cohort_accumulate(self, params, xs, ys, weights, faults=None):
        """Stream the stacked users through `lax.scan` in cohorts of
        C = WirePath.cohort_size: each chunk runs local AdaGrad + the
        fused packed encode, and the weighted dequant-reduce folds into
        a carried [d] accumulator (``mixed_res_wire_reduce(acc=...)``)
        — the dense [U, d] gradient matrix never exists at any fan-in.

        The user axis is zero-padded up to a multiple of C; padded
        slots carry weight 0 and so contribute exactly +-0.0 to the
        fold (DESIGN.md §12).  Returns ``(acc [d] f32, head [U, 8])``
        with the padded rows stripped from the headers.

        ``faults`` (resilience path, DESIGN.md §14) adds per-chunk
        inject + detect: bad users' weights zero out inside the fold
        and the carried good-weight total comes back so the CALLER can
        renormalize the whole accumulator GLOBALLY — per-chunk
        renormalization would misweight chunks against each other.
        Resilient returns ``(acc, head, ok [U], wsum, wsum_good)``."""
        q, d, C = self.quantizer, self.d, self._cohort
        wp = self.wire_path_spec
        U = xs.shape[0]
        Gc = -(-U // C)
        pad = Gc * C - U
        resilient = faults is not None
        guards_on = resilient and self._resilience.guards
        wsum = jnp.sum(weights) if resilient else None
        if resilient:
            faults = dict(faults)
        if pad:
            padu = lambda a: jnp.pad(a, [(0, pad)] + [(0, 0)]
                                     * (a.ndim - 1))
            xs, ys, weights = padu(xs), padu(ys), padu(weights)
            if resilient:
                faults = {k: padu(v) for k, v in faults.items()}
        chunk = lambda a: a.reshape((Gc, C) + a.shape[1:])

        def body(acc, args):
            x_c, y_c, w_c = args
            flat = self._batched_local(params, x_c, y_c)  # [C, d]
            wire = mixed_res_encode(flat, q.lambda_, q.b, path=wp)
            acc = mixed_res_wire_reduce(wire, w_c, q.b, d, acc=acc,
                                        path=wp)
            return acc, wire.head

        def body_r(carry, args):
            acc, wg = carry
            x_c, y_c, w_c, f_c = args
            flat = self._batched_local(params, x_c, y_c)  # [C, d]
            flat = _rg.inject_delta_faults(flat, f_c)
            wire = mixed_res_encode(flat, q.lambda_, q.b, path=wp)
            wire = _rg.inject_bitflips(wire, f_c)
            good = ~f_c["drop"]
            if guards_on:
                # head-based O(C) detection: H_INF is a NaN-propagating
                # max|row|, and zeroing a bad row's head makes its
                # planes decode to exactly 0 (guards.sanitize_head) —
                # no second [C, d] isfinite/sanitize pass
                good = good & _rg.head_finite(wire)
                wire = _rg.sanitize_head(wire, good)
            ok = _rg.payload_ok(good, wire,
                                wp.checksum and guards_on)
            # zero bad users out of the fold; the global renorm (one
            # rescale over the full carried sum) happens in the caller
            w_eff = jnp.where(ok, w_c, 0.0)
            acc = mixed_res_wire_reduce(wire, w_eff, q.b, d, acc=acc,
                                        path=wp)
            return (acc, wg + jnp.sum(w_eff)), (wire.head, ok)

        if not resilient:
            acc, heads = jax.lax.scan(
                body, jnp.zeros((d,), jnp.float32),
                (chunk(xs), chunk(ys), chunk(weights)))
            return acc, heads.reshape(Gc * C, -1)[:U]
        (acc, wsum_good), (heads, oks) = jax.lax.scan(
            body_r, (jnp.zeros((d,), jnp.float32), jnp.float32(0.0)),
            (chunk(xs), chunk(ys), chunk(weights),
             {k: chunk(v) for k, v in faults.items()}))
        return (acc, heads.reshape(Gc * C, -1)[:U],
                oks.reshape(-1)[:U], wsum, wsum_good)

    def _build_train_flat(self):
        """One jit dispatch: all K users' local AdaGrad runs + stacked
        delta flattening -> [K, d].  Quantization/aggregation stay
        eager so the dense path replays the sequential loop's per-op
        rounding exactly (see module docstring)."""
        fn = _obs.retrace_probe(f"sim.train_flat/{self._obs_name}")(
            lambda params, xs, ys: self._batched_local(params, xs, ys))
        if self._user_sharding is not None:
            return jax.jit(fn, in_shardings=(
                self._repl_sharding, self._user_sharding,
                self._user_sharding))
        return jax.jit(fn)

    def _build_fused_step_fn(self):
        """The fully fused per-round step (train + batched quantize +
        aggregation + model update), returned UNJITTED so the replicate
        axis can vmap it before compilation."""
        q, spec, K = self.quantizer, self.spec, self.K
        plane, cohort = self._plane, self._cohort
        wp = self.wire_path_spec
        segments = self._segments   # static per-layer budget (or None)

        # per-round straggler/payload stats streamed from INSIDE the
        # compiled step via jax.debug.callback (repro.obs jit tap) —
        # gated at trace time, so without an active session the step
        # compiles to the identical program (tests/test_obs.py)
        def tap(bits, aux, active):
            # same masking as RoundWork.bits_np: absent users carry 0
            masked = bits * active
            stats = {"bits_min": jnp.min(masked),
                     "bits_median": jnp.median(masked),
                     "bits_p95": jnp.percentile(masked, 95.0),
                     "bits_mean": jnp.mean(masked),
                     "active_frac": jnp.mean(active)}
            if "s" in aux:
                # high-res fraction averaged over ACTIVE users, as in
                # RoundWork.mean_s
                stats["mean_s"] = (jnp.sum(aux["s"] * active)
                                   / jnp.maximum(jnp.sum(active), 1.0))
            _obs.jit_tap("engine.jit_round", stats)

        def step(params, qstate, xs, ys, weights, active):
            if plane == "packed" and cohort is not None:
                # streaming cohorts: the scan body trains + encodes C
                # users at a time and folds their packed planes into
                # the carried [d] accumulator — no [K, d] buffer
                acc, head = self._cohort_accumulate(params, xs, ys,
                                                    weights)
                bits, aux = self._head_stats(head)
                params = jax.tree_util.tree_map(
                    lambda p, u: p + u, params,
                    unflatten_pytree(acc, spec))
                tap(bits, aux, active)
                return params, qstate, bits, aux
            flat = self._batched_local(params, xs, ys)
            if plane == "packed":
                # fully fused quantize-to-wire: reductions, packed
                # planes and the weighted dequant-reduce all happen in
                # the mixed-res kernel suite; no dense recon, and no
                # quantizer state (mixed-resolution is stateless).
                # Under a per-layer budget the encode/reduce runs once
                # per segment with that group's (lambda_, b); bits is
                # the exact per-segment sum (DESIGN.md §13)
                if segments is not None:
                    agg, bits, aux = segmented_wire_aggregate(
                        flat, weights, segments, path=wp)
                else:
                    agg, bits, aux = _wire_aggregate(flat, weights,
                                                     q.lambda_, q.b,
                                                     path=wp)
                params = jax.tree_util.tree_map(
                    lambda p, u: p + u, params,
                    unflatten_pytree(agg, spec))
                tap(bits, aux, active)
                return params, qstate, bits, aux
            if segments is not None:
                # dense plane, per-layer budget: per-segment stateless
                # mixed-resolution quantize + the einsum aggregation
                recon, bits, aux = segmented_quantize(flat, segments)
                agg = jnp.einsum("k,kd->d", weights, recon)
                params = jax.tree_util.tree_map(
                    lambda p, u: p + u, params,
                    unflatten_pytree(agg, spec))
                tap(bits, aux, active)
                return params, qstate, bits, aux
            res, new_qstate = q.batched(flat, qstate)
            if new_qstate is not None:
                # absent users did not transmit: freeze their state
                new_qstate = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(
                        jnp.reshape(active, (K,) + (1,) * (n.ndim - 1))
                        > 0, n, o),
                    new_qstate, qstate)
            if plane == "signplane":
                agg = _signplane_aggregate(flat, res.recon,
                                           res.aux["dw_q"], weights)
            else:
                agg = jnp.einsum("k,kd->d", weights, res.recon)
            params = jax.tree_util.tree_map(
                lambda p, u: p + u, params, unflatten_pytree(agg, spec))
            tap(res.bits, res.aux, active)
            return params, new_qstate, res.bits, res.aux

        if self._resilience is None:
            return step

        # ---- resilience variant (DESIGN.md §14): same arithmetic with
        # inject/detect/quarantine threaded through.  Faults arrive as
        # plain arrays (host-drawn, repro.resilience.faults) so nothing
        # here branches on them; every guard is where-gated, keeping a
        # no-fault round bit-for-bit with the pristine step above
        # (tests/test_resilience.py parity battery).
        guards_on = self._resilience.guards
        d = self.d

        def finish(params, qstate, agg, ok, bits, aux, active):
            """Shared epilogue: quarantine accounting, the final finite
            guard on the aggregated update (freeze the global model for
            the round when everything failed), param update."""
            new = jax.tree_util.tree_map(
                lambda p, u: p + u, params, unflatten_pytree(agg, spec))
            if guards_on:
                okall = _rg.update_ok(agg) & jnp.any(ok)
                params = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(okall, n, o), new, params)
            else:
                okall = jnp.asarray(True)
                params = new
            aux = dict(aux)
            aux["quarantined"] = _rg.quarantined_count(ok, active)
            aux["update_ok"] = okall
            tap(bits, aux, active)
            return params, qstate, bits, aux

        def step_r(params, qstate, xs, ys, weights, active, faults):
            if plane == "packed" and cohort is not None:
                acc, head, ok, wsum, wsum_good = self._cohort_accumulate(
                    params, xs, ys, weights, faults=faults)
                bits, aux = self._head_stats(head)
                # GLOBAL renormalization across all chunks: one rescale
                # of the carried sum, gated so the no-fault fold keeps
                # its exact bits
                any_bad = ~jnp.all(ok)
                scale = wsum / jnp.where(wsum_good > 0, wsum_good, 1.0)
                acc = jnp.where(any_bad, acc * scale, acc)
                return finish(params, qstate, acc, ok, bits, aux,
                              active)
            flat = self._batched_local(params, xs, ys)
            flat = _rg.inject_delta_faults(flat, faults)
            good = ~faults["drop"]
            if plane == "packed" and segments is None:
                # decomposed _wire_aggregate — identical op sequence,
                # with the in-transit bitflip + checksum verify between
                # encode and decode.  Detection reads the encode's own
                # header (head_finite/sanitize_head): O(K) on the
                # 8-float heads instead of an O(K d) isfinite pass +
                # a second [K, d] sanitized buffer
                wire = mixed_res_encode(flat, q.lambda_, q.b, path=wp)
                wire = _rg.inject_bitflips(wire, faults)
                if guards_on:
                    good = good & _rg.head_finite(wire)
                    wire = _rg.sanitize_head(wire, good)
                ok = _rg.payload_ok(good, wire,
                                    wp.checksum and guards_on)
                w_eff, _ = _rg.quarantine_weights(weights, ok)
                agg = mixed_res_wire_reduce(wire, w_eff, q.b, d,
                                            path=wp)
                bits, aux = self._head_stats(wire.head)
                return finish(params, qstate, agg, ok, bits, aux,
                              active)
            if guards_on:
                # dense/segmented recons: NaN rides the payload itself
                # (NaN * 0 = NaN), so bad rows must be zeroed in the
                # delta matrix before quantization
                good = good & _rg.finite_rows(flat)
                flat = _rg.sanitize_rows(flat, good)
            if plane == "packed":
                # per-layer budget: delta-level faults + quarantine
                # only (bitflips/checksums are per-segment wires —
                # not modeled; the flip draw is ignored here)
                ok = good
                w_eff, _ = _rg.quarantine_weights(weights, ok)
                agg, bits, aux = segmented_wire_aggregate(
                    flat, w_eff, segments, path=wp)
                return finish(params, qstate, agg, ok, bits, aux,
                              active)
            ok = good
            w_eff, _ = _rg.quarantine_weights(weights, ok)
            if segments is not None:
                recon, bits, aux = segmented_quantize(flat, segments)
                agg = jnp.einsum("k,kd->d", w_eff, recon)
                return finish(params, qstate, agg, ok, bits, aux,
                              active)
            res, new_qstate = q.batched(flat, qstate)
            if new_qstate is not None:
                # quarantined users did not (effectively) transmit:
                # freeze their state along with the absent users'
                commit = jnp.where(ok, active, 0.0)
                new_qstate = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(
                        jnp.reshape(commit, (K,) + (1,) * (n.ndim - 1))
                        > 0, n, o),
                    new_qstate, qstate)
                qstate = new_qstate
            if plane == "signplane":
                agg = _signplane_aggregate(flat, res.recon,
                                           res.aux["dw_q"], w_eff)
            else:
                agg = jnp.einsum("k,kd->d", w_eff, res.recon)
            return finish(params, qstate, agg, ok, res.bits, res.aux,
                          active)

        return step_r

    def _jit_fused_step(self, step):
        # params and quantizer state are round-to-round carries: donate
        # them so XLA reuses their buffers instead of copying every
        # round (start_run hands the step private copies, so the
        # engine's own init arrays survive repeated runs)
        step = _obs.retrace_probe(
            f"sim.fused_step/{self._obs_name}")(step)
        if self._user_sharding is not None:
            us, rs = self._user_sharding, self._repl_sharding
            # params replicated; every stacked [K, ...] arg (quantizer
            # state, minibatches, weights, activity mask — and the
            # resilience fault-mask dict, when threaded) user-sharded
            shardings = (rs, us, us, us, us, us)
            if self._resilience is not None:
                shardings = shardings + (us,)
            return jax.jit(step, in_shardings=shardings,
                           donate_argnums=(0, 1))
        return jax.jit(step, donate_argnums=(0, 1))

    def _replicated_step(self, R: int):
        """The per-round step over a leading replicate axis R — ONE
        jitted dispatch for all R trajectories.

        R == 1 routes through the SAME compiled function as the
        unreplicated driver (``self._fused_step`` on squeezed arrays):
        a vmap over a singleton axis recompiles the graph with batched
        lowerings and is only roundoff-equal, while the squeeze keeps
        the R=1 replicated path bit-for-bit with today's driver
        (tests/test_mc_replicates.py).
        """
        if R not in self._repl_step_cache:
            if R == 1:
                fused = self._fused_step

                def step1(params, qstate, xs, ys, weights, active,
                          *rest):
                    sq = lambda tr: jax.tree_util.tree_map(
                        lambda x: x[0], tr)
                    p, q, bits, aux = fused(sq(params), sq(qstate),
                                            xs[0], ys[0], weights[0],
                                            active[0],
                                            *[sq(r) for r in rest])
                    ex = lambda tr: jax.tree_util.tree_map(
                        lambda x: x[None], tr)
                    return ex(p), ex(q), bits[None], ex(aux)

                self._repl_step_cache[R] = step1
            else:
                if self._user_sharding is not None:
                    warnings.warn(
                        "EngineConfig.mesh user-axis sharding is not "
                        "supported in replicated mode (R > 1); running "
                        "unsharded", stacklevel=2)
                fn = self._fused_step_fn
                mode = self.engine_cfg.replicate_batching
                if mode == "auto":
                    mode = "vmap" if jax.default_backend() in (
                        "tpu", "gpu") else "map"
                if self._plane in ("signplane", "packed"):
                    # the Pallas wire-format kernels expect their
                    # unbatched [G*W, 128] windows — never vmap them
                    mode = "map"
                # the stacked params/qstate carries are donated round
                # to round, same as the unreplicated fused step
                probe = _obs.retrace_probe(
                    f"sim.replicated_step/{self._obs_name}/R{R}")
                if mode == "map":
                    # on-device loop INSIDE the one jitted dispatch:
                    # per-replicate convs keep the fast unbatched CPU
                    # lowering (see EngineConfig.replicate_batching).
                    # *args: the resilient step carries a trailing
                    # fault-mask dict after the six standard operands
                    self._repl_step_cache[R] = jax.jit(
                        probe(lambda *args: jax.lax.map(
                            lambda a: fn(*a), args)),
                        donate_argnums=(0, 1))
                else:
                    self._repl_step_cache[R] = jax.jit(
                        probe(jax.vmap(fn)), donate_argnums=(0, 1))
        return self._repl_step_cache[R]

    # ------------------------------------------------- async machinery
    # The async round splits the fused step in two: a train+quantize
    # dispatch producing the fresh device payloads (no aggregation, no
    # param update) and, after the host event clock has decided who
    # arrived, an aggregate+buffer-shuffle dispatch.  Still a constant
    # number of jitted calls per round regardless of K and R
    # (tests/test_async_engine.py counts them).
    def _build_async_train_fn(self):
        """Unjitted (params, qstate, xs, ys, commit) ->
        (payload, new_qstate, bits, aux).  ``commit`` is the
        fresh-uploader mask: only committing users' quantizer state
        advances (busy/absent users did not transmit)."""
        q, K, d = self.quantizer, self.K, self.d
        plane, wp = self._plane, self.wire_path_spec

        def tap(bits, aux, commit):
            masked = bits * commit
            stats = {"bits_min": jnp.min(masked),
                     "bits_median": jnp.median(masked),
                     "bits_p95": jnp.percentile(masked, 95.0),
                     "bits_mean": jnp.mean(masked),
                     "active_frac": jnp.mean(commit)}
            if "s" in aux:
                stats["mean_s"] = (jnp.sum(aux["s"] * commit)
                                   / jnp.maximum(jnp.sum(commit), 1.0))
            _obs.jit_tap("engine.jit_round", stats)

        def train(params, qstate, xs, ys, commit):
            flat = self._batched_local(params, xs, ys)
            if plane == "packed":
                wire = mixed_res_encode(flat, q.lambda_, q.b, path=wp)
                bits, aux = self._head_stats(wire.head)
                tap(bits, aux, commit)
                return wire, qstate, bits, aux
            res, new_qstate = q.batched(flat, qstate)
            if new_qstate is not None:
                new_qstate = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(
                        jnp.reshape(commit, (K,) + (1,) * (n.ndim - 1))
                        > 0, n, o),
                    new_qstate, qstate)
            tap(res.bits, res.aux, commit)
            return res.recon, new_qstate, res.bits, res.aux

        if self._resilience is None:
            return train

        # resilience variant (DESIGN.md §14): a quarantined payload is
        # equivalent to an upload that never started — the host folds
        # aux["payload_ok"] into the fresh mask, so the event clock
        # carries no in-flight record and the buffer never sees it.
        # Packed payloads are neutralized by zeroing the wire header
        # (O(K)); dense recons need the bad rows zeroed BEFORE
        # quantization, since NaN * 0 = NaN would otherwise poison the
        # aggregate through a weight-0 slot.
        guards_on = self._resilience.guards

        def train_r(params, qstate, xs, ys, commit, faults):
            flat = self._batched_local(params, xs, ys)
            flat = _rg.inject_delta_faults(flat, faults)
            good = ~faults["drop"]
            if plane == "packed":
                # head-based detection (see step_r): a quarantined
                # wire's zeroed head decodes to exactly 0 even if it
                # lingers in the staleness buffer
                wire = mixed_res_encode(flat, q.lambda_, q.b, path=wp)
                wire = _rg.inject_bitflips(wire, faults)
                if guards_on:
                    good = good & _rg.head_finite(wire)
                    wire = _rg.sanitize_head(wire, good)
                ok = _rg.payload_ok(good, wire,
                                    wp.checksum and guards_on)
                bits, aux = self._head_stats(wire.head)
                aux = dict(aux)
                aux["payload_ok"] = ok
                tap(bits, aux, commit)
                return wire, qstate, bits, aux
            if guards_on:
                good = good & _rg.finite_rows(flat)
                flat = _rg.sanitize_rows(flat, good)
            res, new_qstate = q.batched(flat, qstate)
            if new_qstate is not None:
                commit_eff = jnp.where(good, commit, 0.0)
                new_qstate = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(
                        jnp.reshape(commit_eff,
                                    (K,) + (1,) * (n.ndim - 1))
                        > 0, n, o),
                    new_qstate, qstate)
            aux = dict(res.aux)
            aux["payload_ok"] = good
            tap(res.bits, aux, commit)
            return res.recon, new_qstate, res.bits, aux

        return train_r

    def _build_async_agg_fn(self):
        """Unjitted (params, fresh, buf, w_fresh, w_buf, move, keep) ->
        (params, new_buf): staleness-weighted aggregation over the
        arrived fresh + buffered payloads (all-zero weights mean no
        arrivals — params pass through unchanged) and the buffer
        shuffle (missed fresh payloads move in, retained misses stay,
        everything else zeroes out)."""
        q, spec, K, d = self.quantizer, self.spec, self.K, self.d
        plane, wp = self._plane, self.wire_path_spec

        def agg(params, fresh, buf, w_fresh, w_buf, move, keep):
            if plane == "packed":
                stacked = jax.tree_util.tree_map(
                    lambda f, bu: jnp.concatenate([f, bu], axis=0),
                    fresh, buf)
                w = jnp.concatenate([w_fresh, w_buf], axis=0)
                upd = mixed_res_wire_reduce(stacked, w, q.b, d, path=wp)
            else:
                upd = (jnp.einsum("k,kd->d", w_fresh, fresh)
                       + jnp.einsum("k,kd->d", w_buf, buf))
            params = jax.tree_util.tree_map(
                lambda p, u: p + u, params, unflatten_pytree(upd, spec))

            def shuffle(f, bu):
                m = jnp.reshape(move, (K,) + (1,) * (f.ndim - 1)) > 0
                kp = jnp.reshape(keep, (K,) + (1,) * (f.ndim - 1)) > 0
                return jnp.where(m, f, jnp.where(kp, bu,
                                                 jnp.zeros_like(bu)))

            new_buf = jax.tree_util.tree_map(shuffle, fresh, buf)
            _obs.jit_tap("engine.async_agg",
                         {"w_fresh_sum": jnp.sum(w_fresh),
                          "w_buf_sum": jnp.sum(w_buf),
                          "buf_occupancy": jnp.mean(move + keep)})
            return params, new_buf

        return agg

    def _async_steps(self, R: Optional[int] = None) -> Tuple:
        """(train, agg) jitted async dispatches for replicate count R
        (None = unreplicated).  R=1 routes through the SAME compiled
        functions as the unreplicated path via squeeze/expand — the
        same idiom (and for the same bit-for-bit reason) as
        ``_replicated_step``."""
        if R not in self._async_step_cache:
            train_fn = self._build_async_train_fn()
            agg_fn = self._build_async_agg_fn()
            probe_t = _obs.retrace_probe(
                f"sim.async_train/{self._obs_name}"
                + ("" if R is None else f"/R{R}"))
            probe_a = _obs.retrace_probe(
                f"sim.async_agg/{self._obs_name}"
                + ("" if R is None else f"/R{R}"))
            if R is None:
                # params survive the train dispatch (the agg dispatch
                # still needs them), so only qstate is donated there;
                # the agg dispatch donates its params + buffer carries
                # (the fresh payload is not donated: only one
                # buffer-shaped output exists for XLA to alias)
                self._async_step_cache[R] = (
                    jax.jit(probe_t(train_fn), donate_argnums=(1,)),
                    jax.jit(probe_a(agg_fn), donate_argnums=(0, 2)))
            elif R == 1:
                train1, agg1 = self._async_steps(None)

                def sq(tr):
                    return jax.tree_util.tree_map(lambda x: x[0], tr)

                def ex(tr):
                    return jax.tree_util.tree_map(lambda x: x[None], tr)

                def train_r1(params, qstate, xs, ys, commit, *rest):
                    pay, qs, bits, aux = train1(sq(params), sq(qstate),
                                                xs[0], ys[0], commit[0],
                                                *[sq(r) for r in rest])
                    return ex(pay), ex(qs), bits[None], ex(aux)

                def agg_r1(params, fresh, buf, w_fresh, w_buf, move,
                           keep):
                    p, nb = agg1(sq(params), sq(fresh), sq(buf),
                                 w_fresh[0], w_buf[0], move[0], keep[0])
                    return ex(p), ex(nb)

                self._async_step_cache[R] = (train_r1, agg_r1)
            else:
                mode = self.engine_cfg.replicate_batching
                if mode == "auto":
                    mode = "vmap" if jax.default_backend() in (
                        "tpu", "gpu") else "map"
                if self._plane == "packed":
                    mode = "map"    # Pallas kernels: unbatched windows
                if mode == "map":
                    batch = lambda fn: (lambda *args: jax.lax.map(
                        lambda a: fn(*a), args))
                else:
                    batch = jax.vmap
                self._async_step_cache[R] = (
                    jax.jit(probe_t(batch(train_fn)),
                            donate_argnums=(1,)),
                    jax.jit(probe_a(batch(agg_fn)),
                            donate_argnums=(0, 2)))
        return self._async_step_cache[R]

    def _init_async_clock(self, R: Optional[int] = None) -> AsyncClock:
        """Empty bounded-staleness buffer: host masks all-clear, device
        payload slots all-zero (a zero slot with weight zero contributes
        exactly nothing to the aggregate)."""
        B = 1 if R is None else R
        K, d = self.K, self.d
        if self._plane == "packed":
            shapes = jax.eval_shape(
                lambda z: mixed_res_encode(z, self.quantizer.lambda_,
                                           self.quantizer.b),
                jax.ShapeDtypeStruct((K, d), jnp.float32))
            zero = lambda sd: jnp.zeros(sd.shape if R is None
                                        else (R,) + sd.shape, sd.dtype)
            buffer = jax.tree_util.tree_map(zero, shapes)
        else:
            buffer = jnp.zeros((K, d) if R is None else (R, K, d),
                               jnp.float32)
        return AsyncClock(
            in_flight=np.zeros((B, K), bool),
            remaining_s=np.zeros((B, K)),
            staleness=np.zeros((B, K), np.int64),
            buffer=buffer)

    # ----------------------------------------------------------- rounds
    def _dense_round(self, params, qstate, xs, ys, weights, active_np):
        """Eager quantize + user-ordered weighted aggregation: replays
        the sequential loop's arithmetic op for op."""
        flat = self._train_flat(params, xs, ys)
        res, new_qstate = self.quantizer.batched(flat, qstate)
        if new_qstate is not None:
            if self.engine_cfg.participation >= 1.0:
                qstate = new_qstate
            else:
                act = jnp.asarray(active_np, jnp.float32)
                qstate = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(
                        jnp.reshape(act, (self.K,) + (1,) * (n.ndim - 1))
                        > 0, n, o),
                    new_qstate, qstate)
        # same left-to-right summation as the sequential Python sum
        agg = None
        for j in range(self.K):
            term = res.recon[j] * weights[j]
            agg = term if agg is None else agg + term
        params = jax.tree_util.tree_map(
            lambda p, u: p + u, params, unflatten_pytree(agg, self.spec))
        return params, qstate, res.bits, res.aux

    # ------------------------------------------------------------- run
    def _draw_faults(self, t: int, R: Optional[int] = None):
        """The round's fault masks as device arrays ([K], or stacked
        [R, K]) — None without a resilience config (the pristine step
        signatures take no faults argument)."""
        if self._resilience is None:
            return None
        plan = self._resilience.faults
        if R is None:
            f = plan.draw(t, self.K)
        else:
            per_r = [plan.draw(t, self.K, replicate=r) for r in range(R)]
            f = {k: np.stack([p[k] for p in per_r]) for k in per_r[0]}
        return {k: jnp.asarray(v) for k, v in f.items()}

    def _draw_active(self, part_rng: np.random.Generator) -> np.ndarray:
        p = self.engine_cfg.participation
        if p >= 1.0:
            return np.ones(self.K)
        mask = part_rng.random(self.K) < p
        if not mask.any():                      # never an empty round
            mask[int(part_rng.integers(self.K))] = True
        return mask.astype(np.float64)

    def _round_weights(self, active: np.ndarray) -> np.ndarray:
        if self.engine_cfg.participation >= 1.0:
            return self.rho                     # exactly the paper's rho
        w = self.rho * active
        return w / w.sum()

    # ----------------------------------------------- round-stepping API
    # run() composes these four stages; repro.sim.phy_driver drives the
    # same stages for a whole grid of cells, replacing the per-cell
    # host solve of stage 3 with one batched device solve per round.
    def start_run(self) -> RunState:
        fl = self.fl
        # private copies: the fused step donates its params/qstate
        # inputs, and the engine's init arrays must survive re-runs
        copy = lambda tr: jax.tree_util.tree_map(
            lambda x: jnp.asarray(x).copy(), tr)
        return RunState(
            params=copy(self.params), qstate=copy(self.qstate),
            chan=self.chan,
            rng=np.random.default_rng(fl.seed),   # sequential-loop stream
            part_rng=np.random.default_rng((fl.seed, 0x5EED)),
            test_x=jnp.asarray(self.test.x),
            test_y=jnp.asarray(self.test.y), logs=[],
            async_clock=self._init_async_clock()
            if self.engine_cfg.async_active else None)

    def train_round(self, state: RunState, t: int) -> RoundWork:
        """Stage 1-2: channel redraw, minibatch draw, the jitted local
        training + quantization + aggregation step.  Updates ``state``
        in place and returns the payload the power stage needs."""
        fl, ecfg = self.fl, self.engine_cfg
        if (ecfg.redraw_channel_every > 0 and state.chan is not None
                and t > 1
                and (t - 1) % ecfg.redraw_channel_every == 0):
            state.chan = make_channel(state.chan.cfg,
                                      seed=ecfg.channel_seed + t)
        # same nested draw order as the sequential loop
        sel = np.stack([
            np.stack([state.rng.choice(shard, self.take, replace=False)
                      for _ in range(fl.L)])
            for shard in self.shards])               # [K, L, b]
        active = self._draw_active(state.part_rng)
        if self._clusters > 1 and not ecfg.async_active:
            # two-level hierarchy: only one cluster's minibatches are
            # transferred (and resident) at a time
            return self._clustered_round(state, t, sel, active)
        xs = jnp.asarray(self.dataset.x[sel])
        ys = jnp.asarray(self.dataset.y[sel])
        faults = self._draw_faults(t)
        if ecfg.async_active:
            # async: busy users (mid-upload) keep transmitting their
            # old payload — only participating, non-busy users start a
            # FRESH upload this round; the aggregation happens later in
            # complete_round_async, once arrivals are known
            clock = state.async_clock
            fresh = active * (~clock.in_flight[0]).astype(np.float64)
            train_step, _ = self._async_steps(None)
            clock.payload, state.qstate, bits, aux = train_step(
                state.params, state.qstate, xs, ys,
                jnp.asarray(fresh, jnp.float32),
                *(() if faults is None else (faults,)))
            clock.uploads_started += int(fresh.sum())
            quarantined = 0
            if faults is not None:
                # quarantined payload == upload that never happened:
                # fold the verdict into the fresh mask BEFORE the event
                # clock sees it
                ok_np = np.asarray(aux["payload_ok"], bool)
                quarantined = int(np.sum(fresh.astype(bool) & ~ok_np))
                fresh = fresh * ok_np
            bits_np = np.asarray(bits, np.float64) * fresh
            s_np = np.asarray(aux["s"], np.float64) if "s" in aux \
                else np.ones(self.K)
            fb = fresh.astype(bool)
            mean_s = float(np.mean(s_np[fb])) if fb.any() else 0.0
            return RoundWork(t=t, bits_np=bits_np, active=fresh,
                             mean_s=mean_s, participating=active,
                             quarantined=quarantined)
        weights = self._round_weights(active)
        if not ecfg.effective_fused:
            state.params, state.qstate, bits, aux = self._dense_round(
                state.params, state.qstate, xs, ys, weights, active)
        else:
            state.params, state.qstate, bits, aux = self._fused_step(
                state.params, state.qstate, xs, ys,
                jnp.asarray(weights, jnp.float32),
                jnp.asarray(active, jnp.float32),
                *(() if faults is None else (faults,)))
        quarantined = int(aux["quarantined"]) if faults is not None \
            else 0
        bits_np = np.asarray(bits, np.float64) * active
        s_np = np.asarray(aux["s"], np.float64) if "s" in aux \
            else np.ones(self.K)
        mean_s = float(np.mean(s_np[active.astype(bool)]))
        return RoundWork(t=t, bits_np=bits_np, active=active,
                         mean_s=mean_s, quarantined=quarantined)

    def _clustered_round(self, state: RunState, t: int, sel: np.ndarray,
                         active: np.ndarray) -> RoundWork:
        """Two-level hierarchy (WirePath.clusters > 1, DESIGN.md §12):
        the K users are split host-side into contiguous AP-cluster
        groups; each group's minibatches are transferred alone and its
        cohort scan produces a partial [d] aggregate on device.  The
        partials combine in fixed cluster order (one tiny dispatch per
        hop) before a single param-update dispatch — neither a [K, d]
        buffer nor the full K-user minibatch stack is ever resident.

        Combining per-cluster partials reassociates the user fold, so
        this path matches ``clusters=1`` only to float32 roundoff
        (DESIGN.md §12), never bit-for-bit."""
        weights = self._round_weights(active)
        groups = np.array_split(np.arange(self.K), self._clusters)
        total, heads = None, []
        for g in groups:
            xs = jnp.asarray(self.dataset.x[sel[g]])
            ys = jnp.asarray(self.dataset.y[sel[g]])
            part, head = self._cluster_step(
                state.params, xs, ys,
                jnp.asarray(weights[g], jnp.float32))
            total = part if total is None \
                else self._combine_partials(total, part)
            heads.append(head)
        state.params = self._apply_update(state.params, total)
        # bits from the SAME jitted _head_stats graph the flat cohort
        # step runs — payload accounting is bitwise cluster-invariant
        bits, aux = self._head_stats_jit(jnp.concatenate(heads, axis=0))
        bits_np = np.asarray(bits, np.float64) * active
        s = np.asarray(aux["s"], np.float64)
        mean_s = float(np.mean(s[active.astype(bool)]))
        return RoundWork(t=t, bits_np=bits_np, active=active,
                         mean_s=mean_s)

    # ------------------------------------------- replicated round API
    # The Monte-Carlo replicate axis (DESIGN.md section 8): R
    # independent trajectories of this engine's problem advance in ONE
    # jitted dispatch per round.  The replicated grid driver
    # (repro.sim.phy_driver) owns the per-(cell, replicate) latency
    # accounting; these methods own training state and RNG-stream
    # folding.
    def _repl_chan_seed(self, r: int, t: int) -> int:
        return (self.engine_cfg.channel_seed
                + r * _REPL_CHANNEL_SEED_STRIDE + t)

    def start_replicated_run(self, R: int) -> ReplicatedRunState:
        if not self.engine_cfg.effective_fused:
            raise ValueError(
                "replicated mode vmaps the fused per-round step; "
                "configure EngineConfig(fused=True)")
        if self._clusters > 1:
            raise ValueError(
                "the two-level cluster hierarchy drives its per-cluster "
                "dispatches from the host; replicated mode is not "
                "supported with WirePath.clusters > 1")
        if R < 1:
            raise ValueError(f"need at least one replicate, got {R}")
        fl = self.fl
        stack = lambda tr: jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), tr)
        chans: List[Optional[ChannelRealization]] = [self.chan]
        for r in range(1, R):
            chans.append(None if self.chan is None else make_channel(
                self.chan.cfg, seed=self._repl_chan_seed(r, 0)))
        return ReplicatedRunState(
            params=stack(self.params), qstate=stack(self.qstate),
            chans=chans,
            # replicate 0 keeps the unreplicated streams bit-for-bit
            rngs=[np.random.default_rng(fl.seed) if r == 0 else
                  np.random.default_rng((fl.seed, _REPL_TAG, r))
                  for r in range(R)],
            part_rngs=[np.random.default_rng((fl.seed, 0x5EED)) if r == 0
                       else np.random.default_rng(
                           (fl.seed, 0x5EED, _REPL_TAG, r))
                       for r in range(R)],
            test_x=jnp.asarray(self.test.x),
            test_y=jnp.asarray(self.test.y),
            async_clock=self._init_async_clock(R)
            if self.engine_cfg.async_active else None)

    def train_round_replicated(self, state: ReplicatedRunState, t: int
                               ) -> ReplicatedRoundWork:
        """All R replicates' (channel redraw, minibatch draw, jitted
        train + quantize + aggregate) for round t — one device
        dispatch.  Updates ``state`` in place."""
        fl, ecfg, R = self.fl, self.engine_cfg, state.R
        if (ecfg.redraw_channel_every > 0 and t > 1
                and (t - 1) % ecfg.redraw_channel_every == 0):
            for r in range(R):
                if state.chans[r] is not None:
                    state.chans[r] = make_channel(
                        state.chans[r].cfg,
                        seed=self._repl_chan_seed(r, t))
        # per replicate, the same nested draw order as train_round
        sel = np.stack([
            np.stack([
                np.stack([rng.choice(shard, self.take, replace=False)
                          for _ in range(fl.L)])
                for shard in self.shards])
            for rng in state.rngs])                  # [R, K, L, b]
        xs = jnp.asarray(self.dataset.x[sel])
        ys = jnp.asarray(self.dataset.y[sel])
        active = np.stack([self._draw_active(prng)
                           for prng in state.part_rngs])      # [R, K]
        faults = self._draw_faults(t, R)
        if ecfg.async_active:
            clock = state.async_clock
            fresh = active * (~clock.in_flight).astype(np.float64)
            train_step, _ = self._async_steps(R)
            clock.payload, state.qstate, bits, aux = train_step(
                state.params, state.qstate, xs, ys,
                jnp.asarray(fresh, jnp.float32),
                *(() if faults is None else (faults,)))
            clock.uploads_started += int(fresh.sum())
            quarantined = None
            if faults is not None:
                ok_np = np.asarray(aux["payload_ok"], bool)
                quarantined = np.sum(fresh.astype(bool) & ~ok_np,
                                     axis=-1).astype(np.int64)
                fresh = fresh * ok_np
            state.rounds_done = t
            bits_np = np.asarray(bits, np.float64) * fresh
            s_np = np.asarray(aux["s"], np.float64) if "s" in aux \
                else np.ones((R, self.K))
            mean_s = np.array([
                float(np.mean(s_np[r][fresh[r].astype(bool)]))
                if fresh[r].any() else 0.0 for r in range(R)])
            return ReplicatedRoundWork(t=t, bits_np=bits_np,
                                       active=fresh, mean_s=mean_s,
                                       participating=active,
                                       quarantined=quarantined)
        weights = np.stack([self._round_weights(a) for a in active])
        step = self._replicated_step(R)
        state.params, state.qstate, bits, aux = step(
            state.params, state.qstate, xs, ys,
            jnp.asarray(weights, jnp.float32),
            jnp.asarray(active, jnp.float32),
            *(() if faults is None else (faults,)))
        quarantined = None if faults is None else \
            np.asarray(aux["quarantined"], np.int64)
        state.rounds_done = t
        bits_np = np.asarray(bits, np.float64) * active
        s_np = np.asarray(aux["s"], np.float64) if "s" in aux \
            else np.ones((R, self.K))
        mean_s = np.array([float(np.mean(s_np[r][active[r].astype(bool)]))
                           for r in range(R)])
        return ReplicatedRoundWork(t=t, bits_np=bits_np, active=active,
                                   mean_s=mean_s,
                                   quarantined=quarantined)

    def complete_round_replicated_async(
            self, state: ReplicatedRunState, work: ReplicatedRoundWork,
            per_user_s: np.ndarray) -> AsyncRoundInfo:
        """Replicated async stage 3.5: R event clocks advance host-side
        and ONE jitted aggregate dispatch updates all R replicates'
        params + buffers.  ``per_user_s``: [R, K] solve latencies."""
        R = state.R
        clock = state.async_clock
        step, info = self._advance_clock(
            clock, work.active, work.participating,
            np.asarray(per_user_s, np.float64))
        _, agg_step = self._async_steps(R)
        f32 = lambda a: jnp.asarray(a, jnp.float32)
        state.params, clock.buffer = agg_step(
            state.params, clock.payload, clock.buffer,
            f32(step.w_fresh), f32(step.w_buf),
            f32(step.move), f32(step.keep))
        clock.payload = None
        self._record_async(work.t, info)
        return info

    def replicate_params(self, state: ReplicatedRunState, r: int):
        """Replicate r's current param pytree (device view)."""
        return jax.tree_util.tree_map(lambda x: x[r], state.params)

    # Both drivers (finish_round below; the replicated lockstep in
    # repro.sim.phy_driver) must apply the SAME eval schedule and
    # budget-stop rule or the R=1 bit-for-bit parity contract breaks —
    # one definition each.
    def eval_due(self, t: int) -> bool:
        return t % self.fl.eval_every == 0 or t == self.fl.T

    def budget_spent(self, cum_latency: float) -> bool:
        return (self.fl.latency_budget_s is not None
                and cum_latency >= self.fl.latency_budget_s)

    def eval_accuracy_replicated(self, state: ReplicatedRunState,
                                 alive: Optional[np.ndarray] = None
                                 ) -> np.ndarray:
        """Test accuracy per replicate [R] (NaN for replicates the
        ``alive`` mask excludes — nobody logs them anymore).
        The spec's accuracy fn is a host minibatch loop, so replicates
        evaluate one at a time — for R = 1 this is the identical call
        the unreplicated path makes (the bit-for-bit parity contract
        covers accuracy too)."""
        accuracy = self.model_spec.accuracy
        accs = np.full(state.R, np.nan)
        rs = range(state.R) if alive is None else np.flatnonzero(alive)
        for r in rs:
            accs[r] = accuracy(self.replicate_params(state, int(r)),
                               state.test_x, state.test_y)
        return accs

    def solve_uplink_host(self, chan: Optional[ChannelRealization],
                          bits_np: np.ndarray, active: np.ndarray
                          ) -> "UplinkSolution":
        """Stage 3 (host reference path): per-cell numpy power solve.

        Returns an :class:`UplinkSolution` always carrying the per-user
        upload-completion times scattered back to the full user axis
        (0 for absent users) — the async event clock's input.  The
        NamedTuple unpacks as the legacy ``(straggler_s, per_user_s)``
        pair."""
        per_user = np.zeros(self.K)
        if self.power is None or chan is None:
            return UplinkSolution(0.0, per_user)
        act_idx = np.flatnonzero(active)
        if len(act_idx) == 0:
            # async corner: every participating user is mid-upload, so
            # nobody transmits fresh payload this round
            return UplinkSolution(0.0, per_user)
        if len(act_idx) == self.K:
            sol = self.power.solve(chan, np.maximum(bits_np, 1.0))
            per_user = np.asarray(sol.latencies, np.float64)
        else:
            # churn: only active users transmit — solve the
            # power-control problem on the sub-channel so
            # absent users neither get power nor interfere
            sol = self.power.solve(
                _subchannel(chan, act_idx),
                np.maximum(bits_np[act_idx], 1.0))
            per_user[act_idx] = np.asarray(sol.latencies, np.float64)
        return UplinkSolution(sol.straggler_latency, per_user)

    def solve_uplink_host_detailed(
            self, chan: Optional[ChannelRealization],
            bits_np: np.ndarray, active: np.ndarray
            ) -> Tuple[float, np.ndarray]:
        """DEPRECATED alias of :meth:`solve_uplink_host`, which now
        returns the full :class:`UplinkSolution` itself."""
        warnings.warn(
            "solve_uplink_host_detailed is deprecated; "
            "solve_uplink_host now returns an UplinkSolution carrying "
            "both straggler_s and latencies", DeprecationWarning,
            stacklevel=2)
        return self.solve_uplink_host(chan, bits_np, active)

    # -------------------------------------------------- async complete
    def _advance_clock(self, clock: AsyncClock, active: np.ndarray,
                       participating: np.ndarray, ell: np.ndarray
                       ) -> Tuple[AsyncClockStep, AsyncRoundInfo]:
        """Run the host event clock and fold the transition into the
        clock's host state + cumulative drop counters.  All inputs
        leading-batched [B, K]."""
        step = advance_async_clock(
            clock.in_flight, clock.remaining_s, clock.staleness, ell,
            active, participating, self.rho, self.engine_cfg.staleness)
        clock.in_flight = step.in_flight
        clock.remaining_s = step.remaining_s
        clock.staleness = step.staleness
        clock.dropped_stale += int(step.dropped_stale.sum())
        clock.dropped_churn += int(step.dropped_churn.sum())
        clock.arrived_total += int(step.arrived.sum())
        n_arr = step.arrived.sum(axis=-1)
        stale_sum = step.arrived_staleness.sum(axis=-1)
        info = AsyncRoundInfo(
            round_uplink_s=step.round_s,
            n_arrived=n_arr,
            mean_staleness=np.divide(
                stale_sum, n_arr, out=np.zeros_like(step.round_s),
                where=n_arr > 0),
            max_staleness_obs=step.arrived_staleness.max(axis=-1),
            straggler_gap_s=step.straggler_gap_s,
            dropped_stale=step.dropped_stale,
            dropped_churn=step.dropped_churn,
            effective_participation=n_arr / float(self.K),
            in_flight_next=step.in_flight.sum(axis=-1))
        return step, info

    def complete_round_async(self, state: RunState, work: RoundWork,
                             per_user_s: np.ndarray) -> AsyncRoundInfo:
        """Async stage 3.5: host event clock + the jitted
        aggregate+buffer-shuffle dispatch.  MUST be called on the
        TRAINING state (the one ``train_round`` advanced) — it updates
        ``state.params``; ``finish_round`` never aggregates."""
        clock = state.async_clock
        step, info = self._advance_clock(
            clock, work.active[None], work.participating[None],
            np.asarray(per_user_s, np.float64)[None])
        _, agg_step = self._async_steps(None)
        f32 = lambda a: jnp.asarray(a[0], jnp.float32)
        state.params, clock.buffer = agg_step(
            state.params, clock.payload, clock.buffer,
            f32(step.w_fresh), f32(step.w_buf),
            f32(step.move), f32(step.keep))
        clock.payload = None
        self._record_async(work.t, info)
        return info

    def _record_async(self, t: int, info: AsyncRoundInfo) -> None:
        if not _obs.enabled():
            return
        _obs.record(
            "engine.async", round=t,
            round_uplink_s=float(np.mean(info.round_uplink_s)),
            arrived=float(np.mean(info.n_arrived)),
            mean_staleness=float(np.mean(info.mean_staleness)),
            max_staleness=int(np.max(info.max_staleness_obs)),
            straggler_gap_s=float(np.mean(info.straggler_gap_s)),
            dropped_stale=int(np.sum(info.dropped_stale)),
            dropped_churn=int(np.sum(info.dropped_churn)),
            effective_participation=float(
                np.mean(info.effective_participation)),
            in_flight=float(np.mean(info.in_flight_next)))

    def finish_round(self, state: RunState, work: RoundWork,
                     uplink: float, verbose: bool = False,
                     async_info: Optional[AsyncRoundInfo] = None,
                     per_user_s: Optional[np.ndarray] = None,
                     power_fallbacks: int = 0) -> bool:
        """Stage 4: latency accounting, eval, logging.  Returns False
        once the latency budget is exhausted (stop stepping).

        Never aggregates — async callers run ``complete_round_async``
        first and pass its ``async_info`` here, so the latency/budget
        burn-down uses the async event clock (the round costs the
        deadline the server actually waited, not the slowest user), and
        the log rows carry staleness/arrival columns.  ``per_user_s``
        (sync path) feeds the straggler-gap metric."""
        from repro.fl.loop import RoundLog

        t = work.t
        if async_info is not None:
            uplink = float(async_info.round_uplink_s[0])
            gap = float(async_info.straggler_gap_s[0])
            eff = float(async_info.effective_participation[0])
            stale = float(async_info.mean_staleness[0])
            dropped = int(async_info.dropped_stale[0]
                          + async_info.dropped_churn[0])
        else:
            gap = 0.0 if per_user_s is None \
                else straggler_gap(per_user_s, work.active)
            eff = float(np.sum(work.active > 0)) / self.K
            stale, dropped = 0.0, 0
        state.cum_latency += uplink + self.comp_lat
        acc = None
        if self.eval_due(t):
            acc = self.model_spec.accuracy(state.params, state.test_x,
                                           state.test_y)
        quarantined = int(getattr(work, "quarantined", 0) or 0)
        state.logs.append(RoundLog(t, work.bits_np, uplink,
                                   self.comp_lat, state.cum_latency,
                                   work.mean_s, acc,
                                   straggler_gap_s=gap,
                                   mean_staleness=stale,
                                   effective_participation=eff,
                                   dropped_uploads=dropped,
                                   quarantined_users=quarantined,
                                   power_fallbacks=int(power_fallbacks)))
        state.rounds_done = t
        if _obs.enabled() and (quarantined or power_fallbacks):
            _obs.record("resilience.quarantine", t=t,
                        quarantined_users=quarantined,
                        power_fallbacks=int(power_fallbacks))
        self._log_round(t, acc, work, uplink, state.cum_latency,
                        verbose, gap=gap)
        return not self.budget_spent(state.cum_latency)

    def _log_round(self, t: int, acc, work, uplink: float,
                   cum_latency: float, verbose: bool,
                   gap: float = 0.0) -> None:
        """Round logging: every round goes to the active obs session;
        the console line (the quickstart's old ``print``) appears only
        under verbose, throttled by EngineConfig.log_every."""
        ecfg = self.engine_cfg
        if _obs.enabled():
            budget = self.fl.latency_budget_s
            _obs.record(
                "engine.round", t=t,
                acc=None if acc is None else float(acc),
                bits_mean=float(work.bits_np.mean()),
                uplink_s=float(uplink), comp_s=float(self.comp_lat),
                cum_latency_s=float(cum_latency),
                mean_s=float(work.mean_s),
                active_users=int(np.sum(work.active > 0)),
                straggler_gap_s=float(gap),
                budget_remaining_s=None if budget is None
                else float(budget - cum_latency))
        if (verbose or ecfg.verbose) and acc is not None:
            every = max(1, ecfg.log_every)
            if (t // self.fl.eval_every) % every == 0 or t == self.fl.T:
                print(f"[round {t:4d}] acc={acc:.4f} "
                      f"bits/user={work.bits_np.mean():.3e} "
                      f"cum_lat={cum_latency:.2f}s")

    def result(self, state: RunState):
        from repro.fl.loop import FLResult
        return FLResult(params=state.params, logs=state.logs,
                        rounds_completed=state.rounds_done)

    def run(self, verbose: bool = False):
        async_on = self.engine_cfg.async_active
        state = self.start_run()
        for t in range(1, self.fl.T + 1):
            with _obs.round_scope(t, quantizer=self.quantizer.name):
                with _obs.scope("train_round") as sc:
                    work = self.train_round(state, t)
                    sc.block(state.params)
                with _obs.scope("solve_uplink"):
                    uplink, per_user = self.solve_uplink_host(
                        state.chan, work.bits_np, work.active)
                info = None
                if async_on:
                    with _obs.scope("complete_async"):
                        info = self.complete_round_async(state, work,
                                                         per_user)
                with _obs.scope("finish_round"):
                    more = self.finish_round(state, work, uplink,
                                             verbose=verbose,
                                             async_info=info,
                                             per_user_s=per_user)
            if not more:
                break
        return self.result(state)
