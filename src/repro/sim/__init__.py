"""repro.sim — vectorized multi-user, multi-scenario FL-over-CFmMIMO
simulation engine.

* :mod:`engine` — all K users' local AdaGrad iterations, quantization
  and aggregation in ONE jit-compiled step (vs one dispatch per user
  per round in the legacy sequential loop);
* :mod:`scenarios` — named workload registry (paper defaults, user
  churn, Monte-Carlo channel redraws, heterogeneous data, K/M grids);
* :mod:`sweep` — scenario x quantizer x power-controller grid runner;
* :mod:`phy_driver` — the batched-phy grid driver: lockstep rounds,
  ONE jitted power solve per power spec per round (repro.phy); with
  ``replicates=R`` also the vmapped Monte-Carlo replicate axis
  (mean/ci95 summaries at one dispatch per quantizer per round);
* :mod:`metrics` — round-log aggregation the benchmark tables consume.
"""
from repro.kernels import WirePath  # the shared wire-path spec

from .engine import (AsyncClock, AsyncRoundInfo, EngineConfig,
                     ReplicatedRoundWork, ReplicatedRunState, RoundWork,
                     RunState, StalenessConfig, UplinkSolution,
                     VectorizedFLEngine, advance_async_clock,
                     staleness_weights, straggler_gap)
from .metrics import summarize_logs, summarize_replicates, write_metrics_csv
from .phy_driver import run_grid_batched
from .scenarios import (SCENARIOS, Scenario, async_scenarios,
                        build_problem, get_scenario, grid_scenarios,
                        list_scenarios, register_scenario)
from .sweep import SweepCell, SweepResult, run_cell, run_grid

__all__ = [
    "AsyncClock", "AsyncRoundInfo", "EngineConfig",
    "ReplicatedRoundWork", "ReplicatedRunState", "RoundWork", "RunState",
    "SCENARIOS", "Scenario", "StalenessConfig", "SweepCell",
    "SweepResult", "UplinkSolution", "VectorizedFLEngine", "WirePath",
    "advance_async_clock", "async_scenarios", "build_problem",
    "get_scenario", "grid_scenarios", "list_scenarios",
    "register_scenario", "run_cell", "run_grid", "run_grid_batched",
    "staleness_weights", "straggler_gap", "summarize_logs",
    "summarize_replicates", "write_metrics_csv",
]
