"""Scenario x quantizer x power-controller sweep runner.

Executes a grid of simulation cells on the vectorized engine and emits
the aggregated round metrics the benchmark tables consume:

    from repro.sim import run_grid
    results = run_grid(["paper-table2", "churn-0.7"],
                       quantizers={"mixed": ("mixed-resolution",
                                             {"lambda_": 0.2, "b": 10}),
                                   "classic": ("classic", {})},
                       powers={"ours": "bisection-lp", "none": None},
                       quick=True, out_csv="runs/sweep.csv")

Each cell builds its problem once, runs the engine, and summarizes the
round logs via repro.sim.metrics.  Quantizer/power specs are either
registry names (with optional kwargs) or ready instances, so the
benchmarks can pass their calibrated objects straight through.

Async scenarios (``async_mode=True`` with a deadline; see
``repro.sim.scenarios.async_scenarios`` for the staleness sweep axes)
run fine through this host-solve runner, but the batched driver
(``repro.sim.run_grid_batched``) is the production path: it keeps one
training track per (quantizer, power) cell — required because async
trajectories depend on the power controller — while still batching the
device power solves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

from repro import obs as _obs
from repro.core.power import PowerController, make_power_controller
from repro.core.quantize import Quantizer, make_quantizer

from .engine import VectorizedFLEngine
from .metrics import summarize_logs, write_metrics_csv
from .scenarios import Scenario, build_problem, get_scenario

QuantSpec = Union[str, Tuple[str, Mapping[str, Any]], Quantizer]
PowerSpec = Union[None, str, Tuple[str, Mapping[str, Any]], PowerController]


def _make_quant(spec: QuantSpec) -> Quantizer:
    if isinstance(spec, Quantizer):
        return spec
    if isinstance(spec, str):
        return make_quantizer(spec)
    name, kwargs = spec
    return make_quantizer(name, **dict(kwargs))


def _make_power(spec: PowerSpec) -> Optional[PowerController]:
    if spec is None or isinstance(spec, PowerController):
        return spec
    if isinstance(spec, str):
        return make_power_controller(spec)
    name, kwargs = spec
    return make_power_controller(name, **dict(kwargs))


@dataclasses.dataclass(frozen=True)
class SweepCell:
    scenario: Scenario
    quantizer_label: str
    power_label: str


@dataclasses.dataclass
class SweepResult:
    cell: SweepCell
    result: Any                    # FLResult
    summary: Dict[str, float]

    def row(self) -> Dict[str, Any]:
        return {"scenario": self.cell.scenario.name,
                "quantizer": self.cell.quantizer_label,
                "power": self.cell.power_label, **self.summary}


def _resolve_scenario(scenario: Union[str, Scenario], quick: bool,
                      latency_budget_s: Optional[float]) -> Scenario:
    scn = get_scenario(scenario) if isinstance(scenario, str) else scenario
    scn = scn.scaled(quick)
    if latency_budget_s is not None:
        scn = dataclasses.replace(scn, latency_budget_s=latency_budget_s)
    return scn


def _make_engine(scn: Scenario, problem, quantizer: QuantSpec,
                 power: PowerSpec, mesh=None,
                 resilience=None) -> VectorizedFLEngine:
    from repro.fl.loop import FLConfig

    train, test, shards, model, chan = problem
    q = _make_quant(quantizer)
    pc = _make_power(power)
    fl = FLConfig(L=scn.L, T=scn.T, batch_size=scn.batch_size,
                  alpha=scn.lr, eval_every=scn.effective_eval_every,
                  latency_budget_s=scn.latency_budget_s, seed=scn.seed)
    ecfg = scn.engine_config()
    if mesh is not None:
        ecfg = dataclasses.replace(ecfg, mesh=mesh)
    if resilience is not None:
        ecfg = dataclasses.replace(ecfg, resilience=resilience)
    return VectorizedFLEngine(train, test, shards, model, q,
                              pc if chan is not None else None, chan,
                              fl, engine=ecfg)


def _to_result(scn: Scenario, engine: VectorizedFLEngine, res,
               labels: Tuple[str, str]) -> SweepResult:
    qlabel = labels[0] or engine.quantizer.name
    plabel = labels[1] or (engine.power.name if engine.power is not None
                           else "none")
    return SweepResult(cell=SweepCell(scn, qlabel, plabel), result=res,
                       summary=summarize_logs(res.logs))


def run_cell(scenario: Union[str, Scenario], quantizer: QuantSpec,
             power: PowerSpec = None, quick: bool = True,
             latency_budget_s: Optional[float] = None,
             verbose: bool = False,
             labels: Tuple[str, str] = ("", ""),
             mesh=None) -> SweepResult:
    """Run one (scenario, quantizer, power) simulation cell.  ``mesh``
    (a jax Mesh with a "data" axis) shards the user axis across
    devices — see EngineConfig.mesh."""
    scn = _resolve_scenario(scenario, quick, latency_budget_s)
    engine = _make_engine(scn, build_problem(scn), quantizer, power,
                          mesh=mesh)
    tags = {"scenario": scn.name,
            "quantizer": labels[0] or engine.quantizer.name}
    if labels[1]:
        tags["power"] = labels[1]
    with _obs.context(**tags):
        return _to_result(scn, engine, engine.run(verbose=verbose),
                          labels)


def run_grid(scenarios: List[Union[str, Scenario]],
             quantizers: Mapping[str, QuantSpec],
             powers: Optional[Mapping[str, PowerSpec]] = None,
             quick: bool = True, out_csv: Optional[str] = None,
             latency_budget_s: Optional[float] = None,
             verbose: bool = False, mesh=None,
             phy_batched: bool = False,
             replicates: Optional[int] = None) -> List[SweepResult]:
    """Run the full scenario x quantizer x power grid.

    Within a scenario the problem (dataset, partition, channel) is
    built once and each quantizer's compiled engine step is reused
    across the power-controller axis (power control is host-side, so
    swapping it does not retrace the jitted step).

    ``phy_batched=True`` routes power control through the batched
    repro.phy solvers instead: all cells of a scenario advance in
    lockstep and each round's power problems are solved in ONE jitted
    device call per power spec (see repro.sim.phy_driver).

    ``replicates=R`` (requires ``phy_batched=True``) runs R
    Monte-Carlo replicates per cell on the vmapped replicate axis and
    reports mean/ci95 summaries — see ``run_grid_batched``.
    """
    if phy_batched:
        from .phy_driver import run_grid_batched
        return run_grid_batched(scenarios, quantizers, powers=powers,
                                quick=quick, out_csv=out_csv,
                                latency_budget_s=latency_budget_s,
                                verbose=verbose, mesh=mesh,
                                replicates=replicates)
    if replicates is not None:
        raise ValueError("replicates requires phy_batched=True (the "
                         "replicate axis lives in the batched driver)")
    powers = powers if powers is not None else {"none": None}
    results: List[SweepResult] = []
    for scenario in scenarios:
        scn = _resolve_scenario(scenario, quick, latency_budget_s)
        problem = build_problem(scn)
        chan = problem[4]
        for qlabel, qspec in quantizers.items():
            engine = None
            for plabel, pspec in powers.items():
                if engine is None:
                    engine = _make_engine(scn, problem, qspec, pspec,
                                          mesh=mesh)
                else:
                    pc = _make_power(pspec)
                    engine.power = pc if chan is not None else None
                with _obs.context(scenario=scn.name, quantizer=qlabel,
                                  power=plabel):
                    results.append(_to_result(
                        scn, engine, engine.run(verbose=verbose),
                        (qlabel, plabel)))
    if out_csv:
        write_metrics_csv([r.row() for r in results], out_csv)
    return results
