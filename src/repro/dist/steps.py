"""Compiled distributed train / prefill / decode steps.

``build_train_step`` realizes Algorithm 1 on the LM zoo at datacenter
scale: every replica group along the data(-and-pod) mesh axes is one
FL "user".  The step

1. splits the microbatch stack ``[L, B, ...]`` into per-replica slabs
   ``[G, L, B/G, ...]`` laid over the replica axes,
2. runs L local SGD iterations per replica via
   ``jax.vmap(..., spmd_axis_name=...)`` — pure GSPMD, so the model-
   parallel einsum partitioning inside ``loss_fn`` is untouched,
3. aggregates the per-replica deltas with
   :func:`repro.dist.aggregate_delta` (compressed wire format; the
   paper's eq. 3 with uniform weights), and
4. applies the aggregated delta to the replicated parameters.

The replica axis deliberately goes through ``vmap`` rather than a
manual ``shard_map`` over the whole step: per-replica semantics are
identical (local batches never mix), while XLA remains free to
partition attention/MoE/SSM internals over the model axis — and the
sort/top-k ops inside the compressor stay on the well-tested GSPMD
batched path.

Which wire realization the aggregation runs is named by the shared
:class:`repro.kernels.WirePath` spec on ``TrainHParams.compressor``
(``CompressorConfig.wire``); callers driving ``aggregate_delta``
manually inside their own shard_map can additionally pick
``WirePath(reduce="ring")`` and pass the static ``axis_sizes`` so the
packed buffers ring-reduce over ``collective_permute`` hops instead of
gathering.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs as _obs
from repro.launch.inputs import serving_window
from repro.models.config import InputShape, ModelConfig
from repro.models.sharding_ctx import logical_axis_rules
from repro.models.transformer import decode_step, forward, loss_fn

from .compressor import CompressorConfig, aggregate_delta
from .sharding import (param_shardings, replica_axes, replica_count,
                       serve_rules, train_rules)


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    """Per-round local-training hyperparameters (paper Table I names)."""
    L_local: int = 1             # local iterations per replica per round
    alpha: float = 0.01          # local SGD step size
    compressor: CompressorConfig = CompressorConfig()
    remat: bool = True


def microbatch(batch: Any, L: int) -> Any:
    """Split a global batch into L gradient-accumulation microbatches:
    every leaf ``[B, ...]`` becomes ``[L, B // L, ...]``."""
    if L < 1:
        raise ValueError(f"L must be >= 1, got {L}")

    def one(leaf):
        B = leaf.shape[0]
        if B % L != 0:
            raise ValueError(
                f"global batch {B} not divisible by L_local={L}")
        return leaf.reshape((L, B // L) + leaf.shape[1:])
    return jax.tree_util.tree_map(one, batch)


# per-build retrace-probe ordinal: each built step gets its own probe
# name, so two builds (different configs) don't read as one step
# silently retracing
_STEP_ORDINAL = [0]


def build_train_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                     hp: TrainHParams) -> Callable:
    """step(params, microbatches) -> (new_params, metrics).

    ``microbatches`` is the output of :func:`microbatch`; metrics carry
    the mean local loss and the static per-replica wire payload of the
    aggregation (``wire_bits_per_replica``).
    """
    hp.compressor.validate()
    window = serving_window(cfg, shape)
    axes = replica_axes(mesh)
    if not axes:
        raise ValueError(
            "build_train_step needs a mesh with a 'data' (and "
            f"optionally 'pod') axis to place replicas on; got axes "
            f"{tuple(mesh.shape)}")
    G = replica_count(mesh)
    spmd_axis = axes if len(axes) > 1 else axes[0]
    rules = train_rules(mesh)

    def step(params: Any, batches: Any) -> Tuple[Any, Dict[str, Any]]:
        with logical_axis_rules(mesh, rules):
            def to_replicas(x):
                # [L, B, ...] -> [G, L, B/G, ...]; replica g owns the
                # contiguous batch rows GSPMD placed on its devices
                L, B = x.shape[0], x.shape[1]
                if B % G != 0:
                    raise ValueError(
                        f"global batch {B} not divisible by the "
                        f"{G} replicas of mesh axes {axes}")
                y = x.reshape((L, G, B // G) + x.shape[2:])
                y = jnp.moveaxis(y, 1, 0)
                return jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, P(spmd_axis)))

            batches_g = jax.tree_util.tree_map(to_replicas, batches)

            def local_train(mb):
                def sgd(w, b):
                    loss, grads = jax.value_and_grad(loss_fn)(
                        w, b, cfg, window, hp.remat)
                    w = jax.tree_util.tree_map(
                        lambda p, g: (p - hp.alpha * g).astype(p.dtype),
                        w, grads)
                    return w, loss
                w, losses = jax.lax.scan(sgd, params, mb)
                delta = jax.tree_util.tree_map(
                    lambda a, b: (a - b).astype(jnp.float32), w, params)
                return delta, losses.mean()

            deltas, losses = jax.vmap(
                local_train, spmd_axis_name=spmd_axis)(batches_g)
            agg, info = aggregate_delta(deltas, None, (), hp.compressor)
            # pin the updated params to the canonical layout so the
            # step's output feeds straight back as its input
            shardings = param_shardings(params, cfg, mesh)
            new_params = jax.tree_util.tree_map(
                lambda p, u, s: jax.lax.with_sharding_constraint(
                    (p + u).astype(p.dtype), s),
                params, agg, shardings)
            metrics = {
                "loss": jnp.mean(losses),
                "wire_bits_per_replica": info["wire_bits_per_replica"],
                "delta_dim": info["d"],
            }
            _obs.jit_tap("dist.train_step",
                         {"loss": metrics["loss"],
                          "wire_bits_per_replica":
                              metrics["wire_bits_per_replica"],
                          "replicas": G})
            return new_params, metrics

    _STEP_ORDINAL[0] += 1
    return _obs.retrace_probe(f"dist.train_step{_STEP_ORDINAL[0]}")(step)


def build_prefill_step(cfg: ModelConfig, mesh: Mesh,
                       shape: InputShape) -> Callable:
    """step(params, batch) -> logits, batch sharded over the replica
    axes and activations over the model axis (no remat: inference)."""
    window = serving_window(cfg, shape)
    rules = serve_rules(mesh, "prefill")

    def step(params: Any, batch: Any) -> jnp.ndarray:
        with logical_axis_rules(mesh, rules):
            logits, _, _ = forward(params, batch, cfg, window,
                                   remat=False)
            return logits

    return step


def build_decode_step(cfg: ModelConfig, mesh: Mesh,
                      shape: InputShape) -> Callable:
    """serve(params, cache, tokens, cache_index) -> (logits, new_cache)."""
    window = serving_window(cfg, shape)
    rules = serve_rules(mesh, "decode")

    def serve(params: Any, cache: Any, tokens: jnp.ndarray,
              cache_index: jnp.ndarray) -> Tuple[jnp.ndarray, Any]:
        with logical_axis_rules(mesh, rules):
            return decode_step(params, cache, tokens, cache_index, cfg,
                               window)

    return serve
