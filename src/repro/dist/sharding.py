"""Mesh layout: parameter / batch / cache sharding specs.

The production mesh is ``("data", "model")`` (multi-pod adds a leading
``"pod"`` axis — see launch/mesh.py).  Replica placement follows the
FL-over-CFmMIMO reading of data parallelism: each data(-and-pod) slice
is one "user" whose local delta meets the others only at the
compressed aggregation point (repro.dist.compressor).

Parameter specs use one uniform rule instead of a per-leaf table: for
every leaf of rank >= 2 the largest dim divisible by the model-axis
size is sharded over ``"model"`` (ties resolve to the later dim, which
prefers the output/vocab/ffn dims the activations are annotated with);
``cfg.fsdp`` additionally lays the largest remaining divisible dim over
``"data"``.  1-D leaves (norms, biases, decay vectors) stay replicated.
Divisibility is checked here so every sharding handed to ``jax.jit``'s
``in_shardings`` is exact; uneven intermediate layouts are left to
GSPMD's constraint propagation inside the step.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.inputs import serving_window
from repro.models.config import InputShape, ModelConfig
from repro.models.transformer import init_cache

# logical-name -> mesh-axis rules handed to models.sharding_ctx.
# Training runs the replica (user) axis through vmap's spmd_axis_name,
# so "batch" must stay unmapped there; serving shards it directly.
MODEL_AXIS_RULES: Dict[str, Any] = {
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    "ffn": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
}


def replica_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that enumerate FL replicas ("users")."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def replica_count(mesh: Mesh) -> int:
    n = 1
    for a in replica_axes(mesh):
        n *= mesh.shape[a]
    return n


def train_rules(mesh: Mesh) -> Dict[str, Any]:
    return {**MODEL_AXIS_RULES, "batch": None, "seq": None,
            "res_seq": "model"}


def serve_rules(mesh: Mesh, kind: str) -> Dict[str, Any]:
    axes = replica_axes(mesh)
    batch = axes if len(axes) > 1 else (axes[0] if axes else None)
    rules = {**MODEL_AXIS_RULES, "batch": batch, "seq": None,
             "res_seq": "model" if kind == "prefill" else None}
    if kind in ("prefill", "decode"):
        rules["expert"] = "model"
    return rules


# ------------------------------------------------------------- params
def _leaf_spec(shape: Tuple[int, ...], mesh: Mesh, fsdp: bool) -> P:
    entries = [None] * len(shape)
    if len(shape) >= 2:
        model = mesh.shape.get("model", 1)
        best = None
        if model > 1:
            for i, s in enumerate(shape):
                if s > 1 and s % model == 0 and \
                        (best is None or s >= shape[best]):
                    best = i
            if best is not None:
                entries[best] = "model"
        if fsdp:
            data = mesh.shape.get("data", 1)
            if data > 1:
                bestd = None
                for i, s in enumerate(shape):
                    if i != best and s > 1 and s % data == 0 and \
                            (bestd is None or s >= shape[bestd]):
                        bestd = i
                if bestd is not None:
                    entries[bestd] = "data"
    return P(*entries)


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """PartitionSpec pytree for a parameter (Shape)pytree."""
    return jax.tree_util.tree_map(
        lambda leaf: _leaf_spec(tuple(leaf.shape), mesh, cfg.fsdp), params)


def param_shardings(params: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """NamedSharding pytree for ``jax.jit`` in_shardings."""
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(
            mesh, _leaf_spec(tuple(leaf.shape), mesh, cfg.fsdp)), params)


def budget_group_specs(params: Any, cfg: ModelConfig, mesh: Mesh
                       ) -> Tuple[Any, Any]:
    """(groups, specs) — the per-layer quantizer routing stacked
    alongside the sharding table (DESIGN.md §13): ``groups`` mirrors
    ``params`` with each leaf replaced by its budget group label
    (embed/norm/matmul, the same classifier LayerBudget resolves
    against), ``specs`` is :func:`param_specs`.  One walk, one leaf
    order — so a sharded runtime can hand each parameter leaf both its
    PartitionSpec and its quantization segment consistently."""
    from repro.core.quantize.layer_budget import classify_leaf

    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    groups = jax.tree_util.tree_unflatten(
        treedef, [classify_leaf(path, leaf) for path, leaf in leaves])
    return groups, param_specs(params, cfg, mesh)


# ------------------------------------------------------------ batches
def _batch_dim_spec(size: int, mesh: Mesh) -> Any:
    axes = replica_axes(mesh)
    if not axes or size % replica_count(mesh) != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def batch_shardings(batch: Any, mesh: Mesh, shape: InputShape) -> Any:
    """Shardings for a train/prefill batch dict: the global batch dim
    (dim 0) is laid over the replica axes when divisible."""
    def one(leaf):
        spec = [None] * leaf.ndim
        spec[0] = _batch_dim_spec(leaf.shape[0], mesh)
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map(one, batch)


def train_input_shardings(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                          params: Any, batch: Any) -> Tuple[Any, Any]:
    """(param, microbatched-batch) shardings for build_train_step.

    ``batch`` is the output of :func:`repro.dist.microbatch`: leaves are
    ``[L, B, ...]`` and the global batch dim (dim 1) goes over the
    replica axes.
    """
    ps = param_shardings(params, cfg, mesh)

    def one(leaf):
        spec = [None] * leaf.ndim
        if leaf.ndim >= 2:
            spec[1] = _batch_dim_spec(leaf.shape[1], mesh)
        return NamedSharding(mesh, P(*spec))
    return ps, jax.tree_util.tree_map(one, batch)


# ------------------------------------------------------------- decode
def decode_cache_shape(cfg: ModelConfig, shape: InputShape) -> Any:
    """ShapeDtypeStruct pytree of the static decode cache."""
    window = serving_window(cfg, shape)
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           jnp.dtype(cfg.dtype), window))


def _batch_at(dim: int, ndim: int, axes) -> P:
    entries = [None] * ndim
    if axes is not None:
        entries[dim] = axes
    return P(*entries)


def decode_shardings(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                     params: Any
                     ) -> Tuple[Any, Any, Any, Any]:
    """(params, cache, tokens, cache_index) shardings for the decode
    step: params over the model axis, cache batch over the replica
    axes, tokens over the replica axes, scalar index replicated.

    The batch dim is located by cache STRUCTURE, not by size matching:
    the per-block-kind entries are layer-stacked states ``[n, B, ...]``
    (batch at dim 1) while the top-level ``enc_out`` is ``[B, S, d]``
    (batch at dim 0) — see models.transformer.init_cache.
    """
    ps = param_shardings(params, cfg, mesh)
    B = shape.global_batch
    axes = _batch_dim_spec(B, mesh)
    cache_shape = decode_cache_shape(cfg, shape)
    cs = {}
    for key, sub in cache_shape.items():
        batch_dim = 0 if key == "enc_out" else 1
        cs[key] = jax.tree_util.tree_map(
            lambda leaf, bd=batch_dim: NamedSharding(
                mesh, _batch_at(bd, leaf.ndim, axes)), sub)
    ts = NamedSharding(mesh, P(axes, None))
    isd = NamedSharding(mesh, P())
    return ps, cs, ts, isd
