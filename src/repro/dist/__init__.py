"""repro.dist — sharded distributed runtime with compressed-aggregation
collectives.

Lays the LM zoo and the FL aggregation state over a ``("data",
"model")`` mesh (``"pod"`` optional in front): the model axis carries
tensor parallelism via GSPMD constraint propagation, the data(+pod)
axes enumerate FL replicas ("users") whose local deltas meet at
:func:`aggregate_delta` — the paper's quantized aggregation (§II-C)
realized as a packed-wire collective: by default the fused
mixed-resolution encode/decode kernels (``repro.kernels.mixed_res``,
DESIGN.md §9 — sign/hi/code planes straight to uint32 buffers, fused
dequant+reduce, no dense recon), with the ``signpack`` /
``sign_dequant_reduce`` sign-plane path kept as the jnp-anchored
reference.  Which realization runs — and whether manual mode gathers
the packed buffers or ring-reduces them over ``collective_permute``
hops — is named by the shared :class:`repro.kernels.WirePath` spec
(``CompressorConfig.wire``; the legacy ``wire_path`` strings keep
working through a deprecation shim).

See DESIGN.md §6 for the mesh layout, sharding rules and wire format;
tests/dist_checks.py exercises the whole surface on an 8-fake-device
mesh.
"""
from repro.kernels import WirePath  # the shared wire-path spec
from repro.models.sharding_ctx import shard_map  # version-portable

from .compressor import (CompressorConfig, aggregate_delta,
                         aggregate_flat_manual, aggregate_flat_stacked,
                         budget_k, mixed_recon, payload_bits,
                         signplane_weighted_aggregate)
from .sharding import (batch_shardings, budget_group_specs,
                       decode_cache_shape, decode_shardings,
                       param_shardings, param_specs, replica_axes,
                       replica_count, train_input_shardings)
from .steps import (TrainHParams, build_decode_step, build_prefill_step,
                    build_train_step, microbatch)

__all__ = [
    "CompressorConfig", "TrainHParams", "WirePath", "aggregate_delta",
    "aggregate_flat_manual", "aggregate_flat_stacked", "batch_shardings",
    "budget_group_specs", "budget_k", "build_decode_step",
    "build_prefill_step",
    "build_train_step", "decode_cache_shape", "decode_shardings",
    "microbatch", "mixed_recon", "param_shardings", "param_specs",
    "payload_bits", "replica_axes", "replica_count", "shard_map",
    "signplane_weighted_aggregate", "train_input_shardings",
]
