"""Compressed cross-replica delta aggregation — the paper's §II-C
mixed-resolution scheme as a datacenter collective.

Every data-parallel replica plays the role of one FL user: it holds a
local model delta and the aggregation point is the cross-replica mean
(eq. 3 with uniform rho).  ``aggregate_delta`` compresses that exchange
with the static-budget wire format (core/quantize/static_budget.py):

* ``kind="none"``   — fp32 all-reduce mean, bit-exact (the baseline and
  the correctness oracle);
* ``kind="mixed"``  — per replica, the k = ceil(s_budget * d) largest-
  magnitude elements are sent on a ``bits``-wide uniform grid anchored
  at the rank-k magnitude ``dw_q`` (high resolution); every element
  additionally contributes one sign bit, reconstructed as
  ``± dw_q / 2`` outside the top-k support (low resolution).

  ``wire`` (a :class:`repro.kernels.WirePath`, shared with the sim
  engine; the legacy ``wire_path`` strings map onto it through a
  deprecation shim) selects the realization of that exchange:

  * plane ``"packed"`` (default; legacy ``"fused"``) — the streaming
    mixed-res kernel suite
    (``kernels/mixed_res.py``, DESIGN.md §9): after the top-k anchor,
    one emit pass packs sign + hi-mask + b-bit code planes straight to
    uint32 wire buffers and ``mixed_res_dequant_reduce`` fuses the
    multi-peer decode with the weighted reduction — no dense
    reconstruction is ever materialized, and in manual mode the
    collective moves exactly the packed wire buffers — one
    ``all_gather`` (``WirePath.reduce="gather"``) or G-1
    ``collective_permute`` ring hops folding through the chunked
    accumulator (``reduce="ring"``, one peer buffer resident per hop);
  * plane ``"signplane"`` (legacy ``"reference"``) — the original jnp
    path (``mixed_recon`` dense
    roundtrip + packed 1-bit plane through ``signpack`` /
    ``sign_dequant_reduce`` + dense high-res correction), kept as the
    golden reference the fused path is tested against.

  Either way the payload is *accounted* at the packed
  sign+idx+code size (see DESIGN.md §6 for the wire-format layout).

Two calling conventions, one semantics:

* **stacked** (``axis_names`` empty) — leaves carry a leading replica
  axis ``[G, ...]`` laid over the data mesh axis by GSPMD; used by
  ``build_train_step`` (vmap over replicas).
* **manual** (``axis_names`` non-empty) — called inside a fully-manual
  ``shard_map`` region; leaves are the replica-local shards and the
  exchange uses ``all_gather``/``pmean`` over the named axes.  Each
  model shard quantizes independently (per-shard top-k), which is the
  TPU-native layout: no cross-shard sort, and Lemma 1 holds per shard
  with the per-shard realized threshold.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantize.static_budget import wire_bits
from repro.kernels import WirePath, check_packed_dim, from_wire_path
from repro.kernels.ops import (mixed_res_encode_anchored,
                               mixed_res_wire_reduce,
                               packed_sign_weighted_sum)


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    """Wire-format selection for ``aggregate_delta``."""
    kind: str = "mixed"          # "none" | "mixed"
    s_budget: float = 0.01       # high-resolution fraction (k = ceil(s*d))
    bits: int = 8                # grid width b; must divide 32
    exact_topk: bool = False     # False may use approx_max_k on TPU
    # DEPRECATED spelling of the wire-path plane: "fused" (packed
    # mixed-res kernels) | "reference" (jnp golden signplane path).
    # New call sites set ``wire=WirePath(...)``; None defers to it.
    wire_path: Optional[str] = None
    # The unified wire-path spec (repro.kernels.WirePath) shared with
    # the sim engine.  plane="packed" is the fused kernel exchange,
    # plane="signplane" the golden reference; reduce="ring" replaces
    # manual mode's all_gather with G-1 collective_permute hops (one
    # packed peer buffer resident per hop, folded through the chunked
    # accumulate — DESIGN.md §12).  None + wire_path=None resolves to
    # the packed default.
    wire: Optional[WirePath] = None

    def resolved_wire(self) -> WirePath:
        """The WirePath this config runs: ``wire`` when set, else the
        legacy ``wire_path`` string through its deprecation shim, else
        the packed (fused) default."""
        if self.wire is not None:
            if self.wire_path is not None:
                raise ValueError(
                    "set CompressorConfig.wire OR the legacy wire_path "
                    f"string, not both (wire={self.wire!r}, "
                    f"wire_path={self.wire_path!r})")
            return self.wire
        if self.wire_path is not None:
            return from_wire_path(self.wire_path)
        return WirePath(plane="packed")

    def validate(self) -> None:
        if self.kind not in ("none", "mixed"):
            raise ValueError(f"unknown compressor kind {self.kind!r}")
        wp = self.resolved_wire()   # raises on unknown legacy strings
        if self.kind == "mixed":
            if wp.plane == "dense":
                raise ValueError(
                    "kind='mixed' moves a compressed plane; use "
                    "WirePath(plane='packed') (fused kernels) or "
                    "'signplane' (reference path)")
            if not (0.0 < self.s_budget <= 1.0):
                raise ValueError(f"s_budget must be in (0, 1], got "
                                 f"{self.s_budget}")
            if self.bits < 2 or 32 % self.bits != 0:
                raise ValueError(f"bits must divide 32 and be >= 2, got "
                                 f"{self.bits}")
            if wp.plane == "packed" and self.bits > 16:
                raise ValueError(
                    "the fused wire kernels store codes in <= 16 bits; "
                    f"got bits={self.bits} (use the signplane "
                    "reference plane)")
        budget = getattr(wp, "effective_budget", None)
        if budget is not None:
            if self.kind != "mixed":
                raise ValueError(
                    "per-layer budgets re-parameterize the mixed "
                    f"compressor per segment; kind={self.kind!r} has "
                    "no (s_budget, bits) to segment")
            if wp.reduce == "ring":
                raise ValueError(
                    "per-layer budgets are not supported on the ring "
                    "reduce yet (one accumulator chain per segment); "
                    "use WirePath(reduce='gather')")
            for rule in budget.rules:
                b = self.bits if rule.b is None else rule.b
                if b < 2 or 32 % b != 0:
                    raise ValueError(
                        f"budget group {rule.group!r}: bits must divide "
                        f"32 and be >= 2, got {b}")
                if wp.plane == "packed" and b > 16:
                    raise ValueError(
                        f"budget group {rule.group!r}: the fused wire "
                        f"kernels store codes in <= 16 bits, got {b}")


def budget_k(d: int, s_budget: float) -> int:
    """Static high-resolution budget for a d-element shard."""
    return max(1, min(d, math.ceil(s_budget * d)))


def payload_bits(d: int, comp: CompressorConfig,
                 segments: Optional[Tuple] = None) -> int:
    """Exact per-replica wire payload for one d-element shard.

    With budget ``segments`` (see :func:`aggregate_delta`) the payload
    is the exact sum of the per-segment wire payloads — the bits-sum
    identity of DESIGN.md §13, on the dist side."""
    if comp.kind == "none":
        return 32 * d
    if segments:
        return sum(
            wire_bits(seg.size, budget_k(seg.size, seg.s_budget),
                      seg.b)
            for seg in segments)
    return wire_bits(d, budget_k(d, comp.s_budget), comp.bits)


def _segment_comp(comp: CompressorConfig, wp: WirePath, seg
                  ) -> CompressorConfig:
    """The sub-config one budget segment runs: the segment's
    (s_budget, bits) over a budget-stripped copy of the wire path, so
    the per-segment call reuses the global single-segment machinery."""
    return dataclasses.replace(
        comp, s_budget=seg.s_budget, bits=seg.b,
        wire=dataclasses.replace(wp, budget=None), wire_path=None)


def _rank_k_values(absx: jnp.ndarray, k: int, exact: bool
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(inf-norm, rank-k magnitude) along the last axis."""
    if not exact and jax.default_backend() == "tpu":
        vals, _ = jax.lax.approx_max_k(absx, k)
    else:
        vals, _ = jax.lax.top_k(absx, k)
    return vals[..., 0], vals[..., -1]


def mixed_recon(flat: jnp.ndarray, comp: CompressorConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Element-wise mixed-resolution roundtrip of ``flat`` ([..., d]).

    Returns (recon, dw_q) where dw_q is the per-row grid anchor (the
    rank-k magnitude).  Equivalent to static_budget_encode+decode but
    threshold-based, so it is batchable and never materializes the
    index plane in the compute graph (ties at rank k land in the
    high-resolution branch for every tied element).
    """
    x = flat.astype(jnp.float32)
    d = x.shape[-1]
    k = budget_k(d, comp.s_budget)
    absx = jnp.abs(x)
    inf, dw_q = _rank_k_values(absx, k, comp.exact_topk)
    levels = 2 ** comp.bits - 1
    step = (inf - dw_q) / levels
    safe_step = jnp.where(step > 0, step, 1.0)
    code = jnp.round((absx - dw_q[..., None]) / safe_step[..., None])
    mags = dw_q[..., None] + code * step[..., None]
    hi = jnp.sign(x) * mags
    lo = jnp.where(x > 0, dw_q[..., None] * 0.5, -dw_q[..., None] * 0.5)
    recon = jnp.where(absx >= dw_q[..., None], hi, lo)
    return recon, dw_q


def _sign_scales(dw_q: jnp.ndarray, G: int) -> jnp.ndarray:
    """Per-peer sign-plane weights for the uniform mean: dw_q_g / (2G)."""
    return (dw_q * (0.5 / G)).astype(jnp.float32)


def lo_plane(flat: jnp.ndarray, dw_q: jnp.ndarray) -> jnp.ndarray:
    """The low-resolution reconstruction plane ``sign(x) * dw_q/2``
    (sign(0) = -1, matching the packed sign-bit convention)."""
    half = dw_q[..., None] * 0.5
    return jnp.where(flat > 0, half, -half)


def signplane_weighted_aggregate(flat: jnp.ndarray, recons: jnp.ndarray,
                                 dw_q: jnp.ndarray,
                                 weights: jnp.ndarray) -> jnp.ndarray:
    """``sum_g weights_g * recons_g`` through the packed wire format.

    The single definition of the mixed-resolution aggregation identity
    (shared by the sim engine's rho-weighted user aggregation and the
    uniform cross-replica mean below): the 1-bit plane reduces inside
    the Pallas kernels with per-peer scales ``w_g * dw_q_g / 2``; the
    high-resolution correction ``recons - lo_plane`` — nonzero only on
    each peer's top-k support — rides a dense weighted reduce.
    """
    low = packed_sign_weighted_sum(
        flat, (weights * dw_q * 0.5).astype(jnp.float32))
    corr = jnp.einsum("g,gd->d", weights, recons - lo_plane(flat, dw_q))
    return low + corr


def aggregate_flat_stacked(flat: jnp.ndarray, comp: CompressorConfig,
                           segments: Optional[Tuple] = None
                           ) -> jnp.ndarray:
    """[G, d] per-replica flat deltas -> [d] compressed mean (GSPMD).

    ``segments``: optional per-layer budget segments tiling [0, d) —
    each runs this same aggregation with its own (s_budget, bits)."""
    flat = flat.astype(jnp.float32)
    G, d = flat.shape
    if comp.kind == "none":
        return jnp.mean(flat, axis=0)
    wp = comp.resolved_wire()
    if segments:
        return jnp.concatenate([
            aggregate_flat_stacked(flat[:, seg.start:seg.start + seg.size],
                                   _segment_comp(comp, wp, seg))
            for seg in segments])
    weights = jnp.full((G,), 1.0 / G, jnp.float32)
    if wp.plane == "packed":
        check_packed_dim(d, where="the packed dist exchange")
        # quantize-to-wire without a dense reconstruction: top-k picks
        # the per-replica anchor, the emit pass packs the wire planes,
        # and the decode+mean runs fused from the packed buffers
        k = budget_k(d, comp.s_budget)
        inf, dw_q = _rank_k_values(jnp.abs(flat), k, comp.exact_topk)
        wire = mixed_res_encode_anchored(flat, inf, dw_q, comp.bits,
                                         path=wp)
        if wp.checksum:
            # decode-side integrity check (DESIGN.md §14): a replica
            # whose packed planes fail the xor-fold word is masked out
            # of the mean with renormalized weights; all-valid leaves
            # the weights bit-for-bit untouched
            from repro.resilience.guards import quarantine_weights
            from repro.kernels.ops import verify_wire
            weights, _ = quarantine_weights(weights, verify_wire(wire))
        return mixed_res_wire_reduce(wire, weights, comp.bits, d,
                                     path=wp)
    recon, dw_q = mixed_recon(flat, comp)
    return signplane_weighted_aggregate(flat, recon, dw_q, weights)


def _ring_wire_reduce(wire, comp: CompressorConfig, wp: WirePath,
                      d: int, axes: Tuple[str, ...],
                      axis_sizes: Optional[Mapping[str, int]]
                      ) -> jnp.ndarray:
    """Ring-reduce the packed wire exchange: G-1 ``ppermute`` hops move
    each peer's packed buffers around the ring, and every hop folds the
    arriving planes into the local [d] accumulator via the chunked
    ``mixed_res_wire_reduce(acc=...)`` — exactly ONE peer's packed
    buffer is resident per hop, so the gathered [G, ...] plane stack
    (let alone a dense [G, d]) never exists.

    Each shard folds the peers in its own rotated ring order, so shards
    agree only to float32 roundoff (ulps), not bitwise — the documented
    reassociation tradeoff of DESIGN.md §12; reduce="gather" keeps the
    order-identical fold.  ``wire``: this shard's planes with leading
    axis 1."""
    if len(axes) != 1:
        raise ValueError(
            f"ring reduce runs over exactly one mesh axis, got {axes}")
    if axis_sizes is None or axes[0] not in axis_sizes:
        raise ValueError(
            "ring reduce needs the static group size: pass "
            f"axis_sizes={{{axes[0]!r}: <size>}} (jax cannot query an "
            "axis size inside a manual shard_map region)")
    G = int(axis_sizes[axes[0]])
    w1 = jnp.full((1,), 1.0 / G, jnp.float32)

    def hop_weight(hop_wire):
        # checksum verified AFTER transport, per hop: a corrupted
        # traveling buffer contributes weight 0 and the final fold
        # renormalizes over surviving peers (bit-neutral when all pass)
        if not wp.checksum:
            return w1, jnp.ones((), jnp.float32)
        from repro.kernels.ops import verify_wire
        ok = verify_wire(hop_wire)
        return jnp.where(ok, w1, 0.0), ok.astype(jnp.float32)[0]

    w_eff, good = hop_weight(wire)
    acc = mixed_res_wire_reduce(wire, w_eff, comp.bits, d, path=wp)
    perm = [(i, (i + 1) % G) for i in range(G)]
    traveling = wire
    for _ in range(G - 1):
        traveling = jax.tree_util.tree_map(
            lambda x: jax.lax.ppermute(x, axes[0], perm), traveling)
        w_eff, ok = hop_weight(traveling)
        good = good + ok
        acc = mixed_res_wire_reduce(traveling, w_eff, comp.bits, d,
                                    acc=acc, path=wp)
    if wp.checksum:
        scale = jnp.float32(G) / jnp.maximum(good, 1.0)
        acc = jnp.where(good < G, acc * scale, acc)
    return acc


def aggregate_flat_manual(flat: jnp.ndarray, comp: CompressorConfig,
                          axis_names: Sequence[str],
                          axis_sizes: Optional[Mapping[str, int]] = None,
                          segments: Optional[Tuple] = None
                          ) -> jnp.ndarray:
    """[d_local] replica-local flat delta -> [d_local] compressed mean
    over the named (manual) mesh axes.  Call inside shard_map.

    ``axis_sizes`` maps axis name -> static group size; required only
    by the ring reduce (``WirePath(reduce="ring")``), which cannot
    query the axis size inside the manual region.  ``segments``: see
    :func:`aggregate_flat_stacked` (validate() rejects ring+budget)."""
    flat = flat.astype(jnp.float32)
    axes = tuple(axis_names)
    if comp.kind == "none":
        return jax.lax.pmean(flat, axes)
    d = flat.shape[0]
    wp = comp.resolved_wire()
    if segments:
        return jnp.concatenate([
            aggregate_flat_manual(flat[seg.start:seg.start + seg.size],
                                  _segment_comp(comp, wp, seg),
                                  axes, axis_sizes)
            for seg in segments])
    if wp.plane == "packed":
        check_packed_dim(d, where="the packed dist exchange")
        # encode the local shard to wire; the collective then moves
        # exactly the accounted wire payload (uint32 planes + 8-lane
        # header), never a dense [G, d] stack
        k = budget_k(d, comp.s_budget)
        inf, dw_q = _rank_k_values(jnp.abs(flat), k, comp.exact_topk)
        wire = mixed_res_encode_anchored(flat[None], inf[None],
                                         dw_q[None], comp.bits, path=wp)
        if wp.reduce == "ring":
            return _ring_wire_reduce(wire, comp, wp, d, axes, axis_sizes)
        # gather: one all_gather of the packed buffers, one fused
        # decode+mean over all G peers
        local = jax.tree_util.tree_map(lambda x: x[0], wire)
        g_wire = jax.lax.all_gather(local, axes)
        G = g_wire.head.shape[0]
        weights = jnp.full((G,), 1.0 / G, jnp.float32)
        if wp.checksum:
            # verified after the gather moved the planes (DESIGN.md §14)
            from repro.resilience.guards import quarantine_weights
            from repro.kernels.ops import verify_wire
            weights, _ = quarantine_weights(weights,
                                            verify_wire(g_wire))
        return mixed_res_wire_reduce(g_wire, weights, comp.bits, d,
                                     path=wp)
    recon, dw_q = mixed_recon(flat, comp)
    from repro.kernels.ops import _default_interpret, sign_pad_len
    from repro.kernels.quant_pack import sign_dequant_reduce, signpack
    interp = _default_interpret()
    d_pad = sign_pad_len(d)
    padded = jnp.pad(flat, (0, d_pad - d)) if d_pad != d else flat
    words = signpack(padded.reshape(-1, 128), interpret=interp)  # [W, 4]
    g_words = jax.lax.all_gather(words, axes)                    # [G, W, 4]
    g_dwq = jax.lax.all_gather(dw_q, axes)                       # [G]
    G = g_words.shape[0]
    low = sign_dequant_reduce(g_words, _sign_scales(g_dwq, G),
                              interpret=interp)
    low = low.reshape(-1)[:d]
    corr = jax.lax.pmean(recon - lo_plane(flat, dw_q), axes)
    return low + corr


def aggregate_delta(deltas: Any, specs: Any, axis_names: Sequence[str],
                    comp: CompressorConfig,
                    axis_sizes: Optional[Mapping[str, int]] = None
                    ) -> Tuple[Any, Dict[str, Any]]:
    """Compressed cross-replica mean of a delta pytree.

    deltas:     pytree of per-replica deltas.  With ``axis_names``
                empty, every leaf carries a leading replica axis
                ``[G, ...]`` (stacked/GSPMD mode); with ``axis_names``
                given, leaves are replica-local shards and the call
                must be inside a shard_map manual over those axes.
    specs:      pytree of PartitionSpecs matching ``deltas`` (leaf
                layout over the non-replica mesh axes).  Kept for the
                wire-format record and future re-constraint; the
                arithmetic does not depend on it.
    axis_names: mesh axes to aggregate over (manual mode), or () / None.
    comp:       CompressorConfig.
    axis_sizes: axis name -> static group size, required only for the
                ring reduce in manual mode (see aggregate_flat_manual).

    Returns ``(aggregated, info)`` where ``aggregated`` mirrors
    ``deltas`` without the replica axis (stacked mode) / shard-local
    (manual mode), in float32, and ``info`` carries the static payload
    accounting: ``wire_bits_per_replica`` is the exact number of bits
    one replica puts on the wire per round (fp32 everything for
    ``none``; packed sign+idx+code planes for ``mixed``).
    ``kind="none"`` reproduces the fp32 mean bit-exactly.
    """
    comp.validate()
    del specs  # layout record only — see docstring
    leaves, treedef = jax.tree_util.tree_flatten(deltas)
    if not leaves:
        return deltas, {"wire_bits_per_replica": 0, "d": 0, "k": 0}
    manual = bool(axis_names)
    # per-layer budget (DESIGN.md §13): resolve the leaf-group segments
    # against the delta tree itself — stacked leaves carry a leading
    # replica axis the offsets must skip
    budget = getattr(comp.resolved_wire(), "effective_budget", None)
    segments = None
    if budget is not None:
        segments = budget.segments_for(
            deltas, default_lambda=0.0, default_b=comp.bits,
            default_s=comp.s_budget,
            skip_leading=0 if manual else 1)
    if manual:
        sizes = [int(leaf.size) for leaf in leaves]
        flat = jnp.concatenate(
            [leaf.reshape(-1).astype(jnp.float32) for leaf in leaves])
        agg = aggregate_flat_manual(flat, comp, axis_names, axis_sizes,
                                    segments=segments)
    else:
        G = leaves[0].shape[0]
        sizes = [int(leaf.size) // G for leaf in leaves]
        flat = jnp.concatenate(
            [leaf.reshape(G, -1).astype(jnp.float32) for leaf in leaves],
            axis=1)
        agg = aggregate_flat_stacked(flat, comp, segments=segments)
    d = int(sum(sizes))
    out_leaves = []
    off = 0
    for leaf, n in zip(leaves, sizes):
        shape = leaf.shape[1:] if not manual else leaf.shape
        out_leaves.append(agg[off:off + n].reshape(shape))
        off += n
    info = {
        "wire_bits_per_replica": payload_bits(d, comp, segments),
        "d": d,
        "k": budget_k(d, comp.s_budget) if comp.kind == "mixed" else 0,
    }
    if segments:
        info["segment_bits"] = tuple(
            wire_bits(seg.size, budget_k(seg.size, seg.s_budget), seg.b)
            for seg in segments)
        info["segments"] = segments
    return jax.tree_util.tree_unflatten(treedef, out_leaves), info
