"""CFmMIMO uplink model — §II-B of the paper (eq. 4-5) + Table I.

M access points with N antennas each serve K single-antenna FL users on
the same time/frequency resource.  Independent Rayleigh fading with
large-scale coefficients beta_m^j from a log-distance pathloss model on
a wrap-around square; tau_p-length pilots with greedy assignment (users
beyond tau_p reuse the pilot with least co-pilot interference, in the
spirit of the algorithm in [Demir & Björnson 2021]); MR combining.

Everything here is closed-form in the large-scale coefficients, so the
whole channel layer is deterministic given user/AP positions: the
achievable rate eq. (4) needs only the coefficient bundle
(A_bar, B_bar, B_tilde, I_M) of eq. (5), which we precompute once per
realization and hand to the power-control solvers.

numpy (not jnp): this is the simulation/control-plane layer that feeds
scipy's LP; K <= 40, M <= 64 — negligible compute.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CFmMIMOConfig:
    """Table I parameters."""
    M: int = 16                    # number of APs
    N: int = 4                     # antennas per AP
    K: int = 20                    # FL users
    bandwidth_hz: float = 20e6     # B
    area_m: float = 1000.0         # wrap-around square side
    pathloss_exp: float = 3.67     # alpha_p
    tau_c: int = 200               # coherence block length
    tau_p: int = 10                # pilot length
    p_max_w: float = 0.1           # p^u = 100 mW
    noise_dbm: float = -94.0       # sigma^2 (incl. 7 dB noise figure)
    ref_pathloss_db: float = -30.5 # pathloss at 1 m

    @property
    def noise_w(self) -> float:
        return 10 ** (self.noise_dbm / 10) / 1000.0

    @property
    def pre_log(self) -> float:
        """B_tau = B (1 - tau_p / tau_c)."""
        return self.bandwidth_hz * (1.0 - self.tau_p / self.tau_c)


@dataclasses.dataclass(frozen=True)
class ChannelRealization:
    """Large-scale realization + the eq. (5) coefficient bundle."""
    cfg: CFmMIMOConfig
    beta: np.ndarray        # [M, K] large-scale fading
    pilot: np.ndarray       # [K] pilot index per user
    gamma: np.ndarray       # [M, K] estimation quality, eq. (5)
    A_bar: np.ndarray       # [K]
    B_bar: np.ndarray       # [K]
    B_tilde: np.ndarray     # [K, K]  (row j, col j'), diag unused
    I_M: np.ndarray         # [K]

    def sinr(self, p: np.ndarray) -> np.ndarray:
        """eq. (5): SINR_j(p) for power-control vector p in [0,1]^K."""
        p = np.asarray(p, dtype=np.float64)
        num = self.A_bar * p
        cross = self.B_tilde @ p - np.diag(self.B_tilde) * p
        den = self.B_bar * p + cross + self.I_M
        return num / den

    def rates(self, p: np.ndarray) -> np.ndarray:
        """eq. (4): achievable uplink rate (bit/s) per user."""
        return self.cfg.pre_log * np.log2(1.0 + self.sinr(p))


def _wrap_dist(a: np.ndarray, b: np.ndarray, side: float) -> np.ndarray:
    """Torus (wrap-around) distances between point sets [.,2] x [.,2]."""
    diff = np.abs(a[:, None, :] - b[None, :, :])
    diff = np.minimum(diff, side - diff)
    return np.sqrt(np.sum(diff ** 2, axis=-1))


def _greedy_pilot_assignment(beta: np.ndarray, tau_p: int) -> np.ndarray:
    """First tau_p users get orthogonal pilots; each later user takes the
    pilot minimizing co-pilot interference at its strongest AP."""
    M, K = beta.shape
    pilot = np.zeros(K, dtype=np.int64)
    for j in range(K):
        if j < tau_p:
            pilot[j] = j
            continue
        m_star = int(np.argmax(beta[:, j]))
        cost = np.array([
            beta[m_star, np.flatnonzero(pilot[:j] == t)].sum()
            for t in range(tau_p)])
        pilot[j] = int(np.argmin(cost))
    return pilot


def _draw_ap_positions(cfg: CFmMIMOConfig, rng: np.random.Generator
                       ) -> np.ndarray:
    """Regular grid of APs (common CFmMIMO deployment), jittered."""
    side = cfg.area_m
    g = int(np.ceil(np.sqrt(cfg.M)))
    xs, ys = np.meshgrid(np.arange(g), np.arange(g))
    pts = (np.stack([xs.ravel(), ys.ravel()], -1)[: cfg.M] + 0.5)
    ap_positions = pts * (side / g) + rng.uniform(-20, 20, (cfg.M, 2))
    return np.mod(ap_positions, side)


def draw_positions(cfg: CFmMIMOConfig, seed: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """(ap_positions [M,2], user_positions [K,2]) — the exact RNG stream
    ``make_channel`` consumes when drawing both, factored out so the
    batched phy layer (repro.phy) can draw identical geometry per
    seed."""
    rng = np.random.default_rng(seed)
    ap_positions = _draw_ap_positions(cfg, rng)
    user_positions = rng.uniform(0, cfg.area_m, (cfg.K, 2))
    return ap_positions, user_positions


def large_scale_fading(cfg: CFmMIMOConfig, ap_positions: np.ndarray,
                       user_positions: np.ndarray) -> np.ndarray:
    """beta [M, K] from the log-distance pathloss model on the torus."""
    dist = np.maximum(_wrap_dist(ap_positions, user_positions, cfg.area_m),
                      1.0)
    pl_db = cfg.ref_pathloss_db - 10.0 * cfg.pathloss_exp * np.log10(dist)
    return 10 ** (pl_db / 10)                      # [M, K]


def make_channel(cfg: CFmMIMOConfig, seed: int = 0,
                 ap_positions: Optional[np.ndarray] = None,
                 user_positions: Optional[np.ndarray] = None
                 ) -> ChannelRealization:
    """Draw positions, compute beta, assign pilots, build eq. (5) terms.

    RNG-stream contract: one default_rng(seed) stream, consumed only
    for the positions NOT supplied — passing ap_positions explicitly
    leaves the user draw as the stream's first consumption, exactly as
    before draw_positions was factored out.
    """
    rng = np.random.default_rng(seed)
    if ap_positions is None:
        ap_positions = _draw_ap_positions(cfg, rng)
    if user_positions is None:
        user_positions = rng.uniform(0, cfg.area_m, (cfg.K, 2))

    beta = large_scale_fading(cfg, ap_positions, user_positions)
    pilot = _greedy_pilot_assignment(beta, cfg.tau_p)
    copilot = (pilot[:, None] == pilot[None, :]).astype(np.float64)  # [K,K]

    sigma2 = cfg.noise_w
    p_p = cfg.tau_p * cfg.p_max_w                  # pilot energy tau_p p^u

    # gamma_m^j, eq. (5): p_p beta^2 / (p_p sum_j' beta_m^j' |phi'^H phi|^2
    #                                   + sigma^2)
    denom = p_p * (beta @ copilot) + sigma2        # [M, K]
    gamma = p_p * beta ** 2 / denom                # [M, K]

    N = float(cfg.N)
    # REPRO NOTE: eq. (5) prints A_bar_j = (sum_m N gamma_m^j) without a
    # square, but the MR coherent beamforming gain in the cited
    # [25, Th. 2] is (sum_m N gamma_m^j)^2 — matching the squared form of
    # the coherent pilot-contamination term in B_tilde.  Without the
    # square the SINR is dimensionally inconsistent (gives ~1e7 SINRs).
    # We implement the [25, Th. 2]-consistent squared numerator.
    A_bar = (N * gamma.sum(axis=0)) ** 2           # [K]
    B_bar = N * (gamma * beta).sum(axis=0)         # [K]
    I_M = N * sigma2 * gamma.sum(axis=0) / cfg.p_max_w

    # B_tilde[j, j'] = sum_m N gamma_m^j beta_m^j'
    #                + |phi_j^H phi_j'|^2 (sum_m N gamma_m^j beta'/beta)^2
    first = N * np.einsum("mj,mk->jk", gamma, beta)
    ratio = np.einsum("mj,mj,mk->jk", gamma, 1.0 / beta, beta) * N
    B_tilde = first + copilot * ratio ** 2
    np.fill_diagonal(B_tilde, 0.0)                 # j' != j sum only

    return ChannelRealization(cfg=cfg, beta=beta, pilot=pilot, gamma=gamma,
                              A_bar=A_bar, B_bar=B_bar, B_tilde=B_tilde,
                              I_M=I_M)


def uplink_latency(bits: np.ndarray, rates: np.ndarray) -> np.ndarray:
    """eq. (12): per-user uplink latency ell_t^j = b_t^j / R_t^j."""
    return np.asarray(bits, np.float64) / np.maximum(rates, 1e-9)


def computation_latency(L: int, dataset_size: int, K: int,
                        cycles_per_sample: float = 1e6,
                        cycles_per_sec: float = 20e9) -> float:
    """Max local computation time ell_c = L |D| a_i / (K nu_i) [27].

    REPRO NOTE: the paper prints nu_i = 20 cycles/s, which would make a
    single round take ~1e9 seconds and is inconsistent with its own 3 s
    total-latency budget (Table III); 20 Gcycles/s (a ~2 GHz, 10-wide
    device) reproduces the paper's regime where uplink latency and
    computation are comparable.  Documented in DESIGN.md §4b.
    """
    return L * dataset_size * cycles_per_sample / (K * cycles_per_sec)
