from .cfmmimo import (CFmMIMOConfig, ChannelRealization, computation_latency,
                      draw_positions, large_scale_fading, make_channel,
                      uplink_latency)
