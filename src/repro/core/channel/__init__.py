from .cfmmimo import (CFmMIMOConfig, ChannelRealization, computation_latency,
                      make_channel, uplink_latency)
