"""Quantization schemes: the paper's mixed-resolution + all benchmarks."""
from .aquila import AquilaQuantizer, aquila_quantize
from .base import QuantResult, Quantizer, flatten_pytree, unflatten_pytree
from .classic import ClassicQuantizer
from .laq import LAQQuantizer, LAQState, laq_quantize
from .layer_budget import (BudgetRule, LayerBudget, Segment, classify_leaf,
                           resolve_segments, segmented_quantize,
                           validate_segments)
from .mixed_resolution import (MixedResolutionQuantizer, lemma1_bound,
                               mixed_resolution_quantize)
from .packing import pack_codes, pack_signs, unpack_codes, unpack_signs
from .static_budget import (StaticPayload, static_budget_decode,
                            static_budget_encode, static_budget_roundtrip,
                            wire_bits)
from .topq import TopQQuantizer, topq_quantize

QUANTIZERS = {
    "mixed-resolution": MixedResolutionQuantizer,
    "classic": ClassicQuantizer,
    "laq": LAQQuantizer,
    "aquila": AquilaQuantizer,
    "top-q": TopQQuantizer,
}


def make_quantizer(name: str, **kwargs) -> Quantizer:
    if name not in QUANTIZERS:
        raise KeyError(f"unknown quantizer {name!r}; have {list(QUANTIZERS)}")
    return QUANTIZERS[name](**kwargs)
