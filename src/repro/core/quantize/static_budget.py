"""Static-budget mixed-resolution quantization — the compiled/TPU path.

XLA needs static shapes, so the compiled distributed-aggregation path
replaces the paper's data-dependent threshold count ``dbar_t^j`` with a
**fixed high-resolution budget** ``k = ceil(s_max * d_shard)`` chosen
per config (calibrated from the simulation layer's measured ``s``):

* the k largest-magnitude elements are the high-resolution set;
* the realized threshold is ``lambda_eff = |x|_(k) / ||x||_inf`` — the
  magnitude ratio at rank k — so Lemma 1 holds verbatim with
  ``lambda_ = lambda_eff`` (it is a per-shard data-dependent constant);
* wire format (all static shapes, all uint32 planes — these are the
  arrays the ICI collective actually moves):
    - sign plane   ceil(d/32)   words (1 bit / element, every element)
    - index plane  k            words
    - code plane   ceil(k*b/32) words (b-bit magnitude codes)
    - scalars      dw_q, step   (2 x f32)

This is the TPU-native realization of the paper's scheme; the dynamic
variable-bit behaviour lives in ``mixed_resolution.py`` (simulation).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .packing import pack_codes, pack_signs, unpack_codes, unpack_signs


class StaticPayload(NamedTuple):
    sign_words: jnp.ndarray   # uint32[ceil(d/32)]
    idx: jnp.ndarray          # uint32[k]
    code_words: jnp.ndarray   # uint32[ceil(k*b/32)]
    dw_q: jnp.ndarray         # f32 scalar — grid anchor
    step: jnp.ndarray         # f32 scalar — grid step


def wire_bits(d: int, k: int, b: int) -> int:
    """Exact payload size in bits for the static wire format."""
    sign_words = -(-d // 32)
    code_words = -(-(k * b) // 32)
    return 32 * (sign_words + k + code_words + 2)


def static_budget_encode(x: jnp.ndarray, k: int, b: int) -> StaticPayload:
    """Encode a flat f32 vector with a fixed top-k high-res budget."""
    x = x.astype(jnp.float32)
    absx = jnp.abs(x)
    vals, idx = jax.lax.top_k(absx, k)
    dw_q = vals[-1]                                   # rank-k magnitude
    inf = vals[0]
    r = inf - dw_q
    levels = 2 ** b - 1
    step = r / levels
    safe_step = jnp.where(step > 0, step, 1.0)
    codes = jnp.round((vals - dw_q) / safe_step).astype(jnp.uint32)
    codes = jnp.where(step > 0, codes, jnp.zeros_like(codes))
    return StaticPayload(sign_words=pack_signs(x),
                         idx=idx.astype(jnp.uint32),
                         code_words=pack_codes(codes, b),
                         dw_q=dw_q, step=step)


def static_budget_decode(p: StaticPayload, d: int, b: int) -> jnp.ndarray:
    """Reconstruct the f32 vector from a StaticPayload."""
    signs = unpack_signs(p.sign_words, d)             # +-1 per element
    recon = signs * (p.dw_q / 2.0)                    # low-res default
    k = p.idx.shape[0]
    codes = unpack_codes(p.code_words, b, k).astype(jnp.float32)
    mags = p.dw_q + codes * p.step
    hi = signs[p.idx.astype(jnp.int32)] * mags
    return recon.at[p.idx.astype(jnp.int32)].set(hi)


def static_budget_roundtrip(x: jnp.ndarray, k: int, b: int) -> jnp.ndarray:
    """encode+decode in one call (the in-compute-graph form)."""
    return static_budget_decode(static_budget_encode(x, k, b), x.size, b)
