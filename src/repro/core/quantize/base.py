"""Quantizer interface shared by the paper's scheme and all benchmarks.

A quantizer maps a local gradient (delta) vector ``delta`` to
``(recon, bits)`` where ``recon`` is the server-side reconstruction
(what arrives after dequantization) and ``bits`` is the number of bits
the user must transmit for that vector in that iteration.  Everything
is pure-functional jnp so the quantizers compose with jit/vmap and with
the distributed aggregation path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantResult:
    """Outcome of quantizing one local delta vector.

    Registered as a pytree so quantizer calls compose with jit/vmap —
    the batched engine (repro.sim) vmaps __call__ over stacked per-user
    deltas and gets a QuantResult whose fields carry a leading K axis.
    """

    recon: jax.Array        # dequantized vector, same shape as the input
    bits: jax.Array         # scalar — total payload bits for this vector
    aux: Dict[str, Any]     # scheme-specific diagnostics (s fraction, ...)


jax.tree_util.register_pytree_node(
    QuantResult,
    lambda r: ((r.recon, r.bits, r.aux), None),
    lambda _, children: QuantResult(*children))


class Quantizer:
    """Stateless quantizer base.  Subclasses implement __call__.

    Stateful schemes (LAQ keeps per-user reference copies) thread their
    state explicitly: ``__call__(delta, state) -> (QuantResult, state)``.
    """

    name: str = "base"

    def init_state(self, dim: int) -> Any:  # noqa: D401
        """Per-user state (None for stateless schemes)."""
        return None

    def __call__(self, delta: jax.Array, state: Any = None
                 ) -> Tuple[QuantResult, Any]:
        raise NotImplementedError

    # ------------------------------------------------ batched entry point
    def init_batched_state(self, K: int, dim: int) -> Any:
        """Stacked per-user state with a leading K axis (None when
        stateless).  The default replicates init_state(dim) K times."""
        state = self.init_state(dim)
        if state is None:
            return None
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), state)

    def batched(self, deltas: jax.Array, states: Any = None
                ) -> Tuple[QuantResult, Any]:
        """Quantize K stacked delta vectors in one vmapped call.

        ``deltas``: [K, d]; ``states``: output of init_batched_state (or
        None).  Returns a QuantResult with leading-K fields plus the
        updated stacked state.  Per-row reductions are taken over the
        same axis as the unbatched path, so results match __call__
        row-for-row bitwise.
        """
        if states is None:
            res = jax.vmap(lambda x: self(x, None)[0])(deltas)
            return res, None
        return jax.vmap(lambda x, s: self(x, s))(deltas, states)


def flatten_pytree(tree) -> Tuple[jax.Array, Any]:
    """Flatten a pytree of arrays into one 1-D f32 vector + spec.

    The spec records each leaf's dtype so :func:`unflatten_pytree` can
    cast back — bf16/f16 params round-trip instead of silently promoting
    the whole model to f32 on the first ``params + update``.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [int(jnp.size(l)) for l in leaves]
    dtypes = [jnp.asarray(l).dtype for l in leaves]
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    return flat, (treedef, shapes, sizes, dtypes)


def unflatten_pytree(flat: jax.Array, spec) -> Any:
    # pre-dtype specs (3-tuple) reconstruct every leaf in flat.dtype,
    # matching the old behaviour for any pickled/stored spec
    treedef, shapes, sizes = spec[:3]
    dtypes = spec[3] if len(spec) > 3 else [flat.dtype] * len(shapes)
    leaves = []
    offset = 0
    for shape, size, dtype in zip(shapes, sizes, dtypes):
        leaves.append(
            jnp.reshape(flat[offset:offset + size], shape).astype(dtype))
        offset += size
    return jax.tree_util.tree_unflatten(treedef, leaves)
