"""Quantizer interface shared by the paper's scheme and all benchmarks.

A quantizer maps a local gradient (delta) vector ``delta`` to
``(recon, bits)`` where ``recon`` is the server-side reconstruction
(what arrives after dequantization) and ``bits`` is the number of bits
the user must transmit for that vector in that iteration.  Everything
is pure-functional jnp so the quantizers compose with jit/vmap and with
the distributed aggregation path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantResult:
    """Outcome of quantizing one local delta vector."""

    recon: jax.Array        # dequantized vector, same shape as the input
    bits: jax.Array         # scalar — total payload bits for this vector
    aux: Dict[str, Any]     # scheme-specific diagnostics (s fraction, ...)


class Quantizer:
    """Stateless quantizer base.  Subclasses implement __call__.

    Stateful schemes (LAQ keeps per-user reference copies) thread their
    state explicitly: ``__call__(delta, state) -> (QuantResult, state)``.
    """

    name: str = "base"

    def init_state(self, dim: int) -> Any:  # noqa: D401
        """Per-user state (None for stateless schemes)."""
        return None

    def __call__(self, delta: jax.Array, state: Any = None
                 ) -> Tuple[QuantResult, Any]:
        raise NotImplementedError


def flatten_pytree(tree) -> Tuple[jax.Array, Any]:
    """Flatten a pytree of arrays into one 1-D vector + treedef/aux."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [int(jnp.size(l)) for l in leaves]
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    return flat, (treedef, shapes, sizes)


def unflatten_pytree(flat: jax.Array, spec) -> Any:
    treedef, shapes, sizes = spec
    leaves = []
    offset = 0
    for shape, size in zip(shapes, sizes):
        leaves.append(jnp.reshape(flat[offset:offset + size], shape))
        offset += size
    return jax.tree_util.tree_unflatten(treedef, leaves)
