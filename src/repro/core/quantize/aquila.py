"""AQUILA-style adaptive bit-width benchmark [Zhao et al., TMC 2024].

AQUILA adapts the per-device, per-round uniform quantization level so
the quantization distortion stays proportional to the update's useful
signal.  We implement the bit-selection rule as: pick the smallest
``b in {b_min..b_max}`` such that the relative l2 quantization error of
b-bit uniform quantization is below ``tol`` — a faithful-in-spirit
reimplementation of AQUILA's distortion-bounded adaptive level choice
(the original derives the level from consecutive-round model deviation;
both reduce bits when updates shrink).  Payload: d*b + 32 bits.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax.numpy as jnp

from .base import QuantResult, Quantizer
from .laq import _uniform_quantize


def aquila_quantize(delta: jnp.ndarray, b_min: int, b_max: int, tol: float
                    ) -> QuantResult:
    x = delta.astype(jnp.float32)
    d = x.size
    norm = jnp.linalg.norm(x)
    safe_norm = jnp.where(norm > 0, norm, 1.0)

    # candidate reconstructions for every allowed bit-width
    recons = jnp.stack([_uniform_quantize(x, b)
                        for b in range(b_min, b_max + 1)])
    rel_err = jnp.linalg.norm(recons - x[None, :], axis=1) / safe_norm
    ok = rel_err <= tol
    # index of the smallest acceptable b; fall back to b_max if none pass
    first_ok = jnp.argmax(ok)
    any_ok = jnp.any(ok)
    idx = jnp.where(any_ok, first_ok, recons.shape[0] - 1)
    recon = recons[idx]
    b_sel = b_min + idx
    bits = jnp.asarray(float(d)) * b_sel + 32.0
    aux = {"s": jnp.asarray(1.0), "b_selected": b_sel,
           "rel_err": rel_err[idx]}
    return QuantResult(recon=recon, bits=bits, aux=aux)


class AquilaQuantizer(Quantizer):
    name = "aquila"

    def __init__(self, b_min: int = 2, b_max: int = 8, tol: float = 0.05):
        if b_min < 2 or b_max < b_min:
            raise ValueError("need 2 <= b_min <= b_max")
        self.b_min, self.b_max, self.tol = int(b_min), int(b_max), float(tol)

    def __call__(self, delta, state: Any = None) -> Tuple[QuantResult, Any]:
        return aquila_quantize(delta, self.b_min, self.b_max, self.tol), state
