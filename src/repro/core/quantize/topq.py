"""Top-q sparsification benchmark [Wangni et al., NeurIPS 2018].

Only the q-fraction largest-magnitude entries are transmitted; each
kept entry costs 32 value bits + ceil(log2 d) index bits; dropped
entries are reconstructed as zero.  The paper compares against Top-q
with q matched to the mixed-resolution scheme's measured s.
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .base import QuantResult, Quantizer


def topq_quantize(delta: jnp.ndarray, q: float) -> QuantResult:
    x = delta.astype(jnp.float32)
    d = x.size
    k = max(1, int(math.ceil(q * d)))
    absx = jnp.abs(x)
    # threshold = k-th largest magnitude; keep everything >= it.
    # top_k is O(d log k) vs the old full jnp.sort's O(d log d) — the
    # k-th order statistic is identical (ties included: both return
    # the same *value*, and the mask keeps every tied element); parity
    # vs the sort is pinned in tests/test_quantize.py.
    thresh = jax.lax.top_k(absx, k)[0][-1]
    mask = absx >= thresh
    recon = jnp.where(mask, x, 0.0)
    idx_bits = math.ceil(math.log2(max(d, 2)))
    bits = jnp.asarray(float(k) * (32.0 + idx_bits))
    return QuantResult(recon=recon, bits=bits,
                       aux={"s": jnp.asarray(k / d), "k": k})


class TopQQuantizer(Quantizer):
    name = "top-q"

    def __init__(self, q: float = 0.01):
        if not (0.0 < q <= 1.0):
            raise ValueError(f"q must be in (0,1], got {q}")
        self.q = float(q)

    def __call__(self, delta, state: Any = None) -> Tuple[QuantResult, Any]:
        return topq_quantize(delta, self.q), state
