"""Per-layer mixed-resolution bit budgets (DESIGN.md §13).

The paper's scheme spends one global ``(lambda_, b)`` budget on the
whole flattened model.  Real sequence models are structurally
heterogeneous — embeddings tolerate coarse grids, norm gains do not,
matmul deltas sit in between (the same observation that drives olmax's
per-parameter optimizer routing).  A :class:`LayerBudget` partitions
the flattened vector into contiguous *segments* of leaves that share a
group label and gives each group its own mixed-resolution budget; the
engine and the dist compressor then run one quantize/encode per
segment and account payload bits as the exact sum of the per-segment
bits.

Contract (pinned by tests/test_layer_budget.py):

* ``LayerBudget.uniform()`` — no rules — routes the pre-existing
  global-budget path and is therefore bit-for-bit identical to
  ``budget=None`` in every engine mode.
* ``resolve_segments``/:meth:`LayerBudget.segments_for` walk the tree
  with ``tree_flatten_with_path``, whose leaf order equals
  ``tree_flatten``'s — the same order :func:`flatten_pytree` and the
  engine's stacked-delta concat use — so segment offsets index the
  flattened vector directly.
* Per-user payload bits under a budget equal
  ``sum_seg [d_seg(b_seg s_seg + 1 - s_seg) + 32]`` exactly (one
  32-bit header per segment).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .mixed_resolution import mixed_resolution_quantize

GROUPS = ("embed", "norm", "matmul")


@dataclasses.dataclass(frozen=True)
class BudgetRule:
    """Budget override for one leaf group.

    Fields left ``None`` fall back to the caller's defaults at
    resolution time (the sim engine fills them from its quantizer, the
    dist compressor from ``CompressorConfig``), so one rule set serves
    both the ``(lambda_, b)`` simulation path and the ``(s_budget,
    bits)`` static-budget dist path.
    """

    group: str
    lambda_: Optional[float] = None   # |x|/||x||_inf threshold (paper eq. 6)
    b: Optional[int] = None           # grid bits for high-res entries
    s_budget: Optional[float] = None  # dist static high-res fraction

    def __post_init__(self):
        if self.group not in GROUPS + ("default",):
            raise ValueError(
                f"unknown budget group {self.group!r}; expected one of "
                f"{GROUPS + ('default',)}")
        if self.lambda_ is not None and not 0.0 <= float(self.lambda_) <= 1.0:
            raise ValueError(f"lambda_ must be in [0, 1], got {self.lambda_}")
        if self.b is not None and int(self.b) < 2:
            raise ValueError(f"b must be >= 2, got {self.b}")
        if self.s_budget is not None and not 0.0 < float(self.s_budget) <= 1.0:
            raise ValueError(
                f"s_budget must be in (0, 1], got {self.s_budget}")


class Segment(NamedTuple):
    """One contiguous run of same-budget leaves in the flattened vector."""

    start: int
    size: int
    lambda_: float
    b: int
    group: str
    s_budget: Optional[float] = None

    @property
    def stop(self) -> int:
        return self.start + self.size


@dataclasses.dataclass(frozen=True)
class LayerBudget:
    """Immutable, hashable per-group budget table.

    Hashable so it can ride on :class:`repro.kernels.WirePath` (itself
    a frozen spec closed over by jitted steps).  An empty rule table is
    the *uniform* budget: consumers must treat it exactly like "no
    budget" and keep their single-segment global path.
    """

    rules: Tuple[BudgetRule, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        seen = set()
        for r in self.rules:
            if not isinstance(r, BudgetRule):
                raise TypeError(f"rules must be BudgetRule, got {type(r)}")
            if r.group in seen:
                raise ValueError(f"duplicate rule for group {r.group!r}")
            seen.add(r.group)

    # ------------------------------------------------------ constructors
    @classmethod
    def uniform(cls) -> "LayerBudget":
        """The identity budget: one global segment, today's exact path."""
        return cls(rules=())

    @classmethod
    def by_group(cls, **budgets) -> "LayerBudget":
        """``LayerBudget.by_group(embed=(0.4, 6), norm=(0.05, 12))`` —
        values are ``(lambda_, b)`` or ``(lambda_, b, s_budget)`` tuples
        or ready-made :class:`BudgetRule` s (group taken from the kwarg).
        """
        rules = []
        for group, spec in sorted(budgets.items()):
            if isinstance(spec, BudgetRule):
                rules.append(dataclasses.replace(spec, group=group))
            else:
                spec = tuple(spec)
                rules.append(BudgetRule(group, *spec))
        return cls(rules=tuple(rules))

    # ------------------------------------------------------------ queries
    @property
    def is_uniform(self) -> bool:
        return not self.rules

    def rule_for(self, group: str) -> Optional[BudgetRule]:
        for r in self.rules:
            if r.group == group:
                return r
        for r in self.rules:
            if r.group == "default":
                return r
        return None

    def segments_for(self, tree, default_lambda: float, default_b: int,
                     default_s: Optional[float] = None,
                     skip_leading: int = 0) -> Tuple[Segment, ...]:
        """Resolve this budget against a concrete params/delta pytree."""
        return resolve_segments(tree, self, default_lambda, default_b,
                                default_s=default_s,
                                skip_leading=skip_leading)


def classify_leaf(path, leaf, skip_leading: int = 0) -> str:
    """Route one leaf to a budget group from its key path + rank.

    Name-based routing first (embedding/unembedding matrices carry
    vocab-shaped rows regardless of rank), then rank: vectors/scalars
    are norm-like gains/biases, rank >= 2 are matmul weights.
    ``skip_leading`` discounts stacked batch axes (the dist stacked
    path carries a leading replica-group axis on every leaf) so a
    stacked norm gain still ranks as a vector.
    """
    name = jax.tree_util.keystr(path).lower()
    if any(tok in name for tok in ("embed", "lm_head", "vocab")):
        return "embed"
    shape = tuple(getattr(leaf, "shape", ()))[skip_leading:]
    if len(shape) <= 1:
        return "norm"
    return "matmul"


def resolve_segments(tree, budget: LayerBudget, default_lambda: float,
                     default_b: int, default_s: Optional[float] = None,
                     skip_leading: int = 0) -> Tuple[Segment, ...]:
    """Partition the flattened vector into contiguous budget segments.

    ``skip_leading`` ignores that many leading axes when sizing leaves
    (the dist stacked path carries a leading replica-group axis G on
    every leaf; offsets must index the per-replica flat vector).
    Adjacent leaves resolving to the same ``(group, lambda_, b,
    s_budget)`` merge into one segment, so a uniform-in-effect rule
    table still collapses to few segments.
    """
    leaves_with_path, _ = jax.tree_util.tree_flatten_with_path(tree)
    segments: list = []
    offset = 0
    for path, leaf in leaves_with_path:
        shape = tuple(getattr(leaf, "shape", ()))[skip_leading:]
        size = 1
        for s in shape:
            size *= int(s)
        group = classify_leaf(path, leaf, skip_leading)
        rule = budget.rule_for(group)
        lam = default_lambda if rule is None or rule.lambda_ is None \
            else float(rule.lambda_)
        b = default_b if rule is None or rule.b is None else int(rule.b)
        s_budget = default_s if rule is None or rule.s_budget is None \
            else float(rule.s_budget)
        if segments and segments[-1].group == group \
                and segments[-1].lambda_ == lam and segments[-1].b == b \
                and segments[-1].s_budget == s_budget:
            prev = segments[-1]
            segments[-1] = prev._replace(size=prev.size + size)
        else:
            segments.append(Segment(offset, size, lam, b, group, s_budget))
        offset += size
    return tuple(segments)


def validate_segments(segments, d: int) -> None:
    """Loud check that segments tile [0, d) contiguously."""
    offset = 0
    for seg in segments:
        if seg.start != offset or seg.size <= 0:
            raise ValueError(
                f"segments must tile the flat vector contiguously: segment "
                f"{seg} at expected offset {offset}")
        offset += seg.size
    if offset != d:
        raise ValueError(
            f"segments cover {offset} entries but the flat vector has {d}")


def segmented_quantize(flat: jax.Array, segments: Tuple[Segment, ...]
                       ) -> Tuple[jax.Array, jax.Array, dict]:
    """Dense-plane per-segment mixed-resolution quantize of [U, d] rows.

    Returns ``(recon [U, d], bits [U], aux)`` where ``bits`` is the
    exact sum of the per-segment payloads (one 32-bit ||.||_inf header
    per segment) and ``aux["segment_bits"]`` is the [U, n_seg]
    breakdown the bits-sum identity test pins.
    """
    U, d = flat.shape
    validate_segments(segments, d)
    recons, seg_bits, dbar = [], [], None
    for seg in segments:
        sl = flat[:, seg.start:seg.stop]
        res = jax.vmap(
            lambda v, lam=seg.lambda_, b=seg.b:
            mixed_resolution_quantize(v, lam, b))(sl)
        recons.append(res.recon)
        seg_bits.append(res.bits)
        db = res.aux["dbar"]
        dbar = db if dbar is None else dbar + db
    recon = jnp.concatenate(recons, axis=1)
    segment_bits = jnp.stack(seg_bits, axis=1)           # [U, n_seg]
    bits = jnp.sum(segment_bits, axis=1)
    aux = {"s": dbar.astype(jnp.float32) / float(d),
           "dbar": dbar.astype(jnp.int32),
           "segment_bits": segment_bits}
    return recon, bits, aux
