"""LAQ — Lazily Aggregated Quantized Gradients [Sun et al., TPAMI 2022].

Each user quantizes the *innovation* of its local gradient relative to
the most recently transmitted quantized gradient, with b-bit uniform
quantization on a grid of radius ``||innovation||_inf``.  A user skips
the upload entirely (lazy aggregation) when the innovation energy is
small relative to the recent history of quantized-update energies:

    ||Q(g_t) - q_{t-1}||^2 <= (xi / D) * sum_{d=1..D} e_{t-d} + 3 eps_t

(we use the simplified energy rule with the 3*eps slack dropped and a
configurable laziness factor xi).  A skipped round costs 0 payload bits;
the server reuses the user's last transmitted value.

State per user: (last transmitted quantized gradient, D recent update
energies).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax.numpy as jnp

from .base import QuantResult, Quantizer


class LAQState(NamedTuple):
    last_sent: jnp.ndarray     # last transmitted quantized vector
    energies: jnp.ndarray      # ring buffer of D recent update energies
    ptr: jnp.ndarray           # ring pointer


def _uniform_quantize(x: jnp.ndarray, b: int) -> jnp.ndarray:
    """b-bit uniform quantization on [-r, r], r = ||x||_inf."""
    r = jnp.max(jnp.abs(x))
    safe_r = jnp.where(r > 0, r, 1.0)
    levels = 2 ** (b - 1) - 1          # symmetric grid incl. sign
    step = safe_r / levels
    q = jnp.round(x / step) * step
    return jnp.where(r > 0, q, jnp.zeros_like(x))


def laq_quantize(delta: jnp.ndarray, state: LAQState, b: int, xi: float
                 ) -> Tuple[QuantResult, LAQState]:
    x = delta.astype(jnp.float32)
    d = x.size
    innovation = x - state.last_sent
    q_innov = _uniform_quantize(innovation, b)
    candidate = state.last_sent + q_innov
    energy = jnp.sum(q_innov ** 2)

    hist = jnp.mean(state.energies)
    # lazy rule: skip when the innovation energy is below xi * history.
    # First rounds (hist == 0) always transmit.
    skip = jnp.logical_and(hist > 0, energy <= xi * hist)

    recon = jnp.where(skip, state.last_sent, candidate)
    bits = jnp.where(skip, 0.0, float(d) * b + 32.0)

    new_energies = state.energies.at[state.ptr].set(
        jnp.where(skip, state.energies[state.ptr], energy))
    new_ptr = jnp.where(skip, state.ptr,
                        (state.ptr + 1) % state.energies.size)
    new_state = LAQState(last_sent=recon, energies=new_energies, ptr=new_ptr)
    aux = {"s": jnp.asarray(1.0), "skipped": skip, "energy": energy}
    return QuantResult(recon=recon, bits=bits, aux=aux), new_state


class LAQQuantizer(Quantizer):
    name = "laq"

    def __init__(self, b: int = 4, xi: float = 0.8, history: int = 10):
        self.b = int(b)
        self.xi = float(xi)
        self.history = int(history)

    def init_state(self, dim: int) -> LAQState:
        return LAQState(last_sent=jnp.zeros((dim,), jnp.float32),
                        energies=jnp.zeros((self.history,), jnp.float32),
                        ptr=jnp.asarray(0, jnp.int32))

    def __call__(self, delta, state: Any = None) -> Tuple[QuantResult, Any]:
        if state is None:
            state = self.init_state(delta.size)
        return laq_quantize(delta, state, self.b, self.xi)
