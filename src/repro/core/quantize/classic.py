"""Classic FL baseline: full-precision (32-bit) transmission."""
from __future__ import annotations

from typing import Any, Tuple

import jax.numpy as jnp

from .base import QuantResult, Quantizer


class ClassicQuantizer(Quantizer):
    """No compression — every element costs 32 bits."""

    name = "classic"

    def __call__(self, delta, state: Any = None) -> Tuple[QuantResult, Any]:
        bits = jnp.asarray(32.0 * delta.size)
        return QuantResult(recon=delta.astype(jnp.float32), bits=bits,
                           aux={"s": jnp.asarray(1.0)}), state
