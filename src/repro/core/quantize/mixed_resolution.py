"""Adaptive mixed-resolution quantization — the paper's §II-C scheme.

Element-wise two-category quantization of a local gradient vector
``delta`` (d elements):

* high-resolution — elements with ``|x_i| / ||x||_inf >= lambda_`` are
  uniformly quantized with ``b`` bits on the grid ``[dw_q, ||x||_inf]``
  of radius ``r = ||x||_inf - dw_q``, where ``dw_q`` is the smallest
  magnitude among high-resolution elements (eq. 6-7);
* low-resolution — every other element is sent as a single sign bit and
  reconstructed as ``± dw_q_hat / 2`` (eq. 8).

Total payload (eq. below (7)): ``b_t = d (b s + 1 - s) + 32`` bits with
``s = dbar / d`` the high-resolution fraction; 32 bits carry the grid
radius.  Lemma 1 bounds the error: ``||delta - recon||_inf <=
c(lambda_, b) ||delta||_inf`` — property-tested in tests/test_quantize.py.

This module is the eager golden reference.  The production encode path
is the fused quantize-to-wire kernel suite (``repro.kernels.mixed_res``
via ``repro.kernels.ops.mixed_res_wire_aggregate``, DESIGN.md §9): two
streaming passes to the packed wire planes, never materializing the
dense ``recon`` — bit accounting exact vs this reference, recon within
a documented ulp bound (tests/test_quant_kernels.py).

Faithfulness notes:
* the paper transmits ``r`` in 32 bits; reconstructing also needs the
  grid anchor ``dw_q`` (or equivalently ``||x||_inf``).  We follow the
  paper's bit accounting (+32) and note the extra scalar would add 32
  bits — immaterial at d >= 1e4.
* ``dw_q`` lies on the grid by construction so ``dw_q_hat == dw_q``;
  Lemma 1's slack for a quantized anchor is therefore not exercised.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax.numpy as jnp

from .base import QuantResult, Quantizer

_F32_BITS = 32.0


def lemma1_bound(lambda_: float, b: int) -> float:
    """The constant ``c_j`` of Lemma 1, eq. (9) — as printed in the paper.

    REPRO FINDING: the paper's low-resolution branch (Appendix A,
    eq. 17) bounds ``eps_i = x_i - dw_q/2`` only from above, using
    ``dw_q >= lambda ||x||_inf``.  When the magnitude spectrum has a
    *gap* at the threshold (``dw_q >> lambda ||x||_inf``) the other
    side dominates: a near-zero element is reconstructed as
    ``+- dw_q / 2``, giving ``|eps| = dw_q/2`` which can exceed
    ``c_j ||x||_inf``.  Eq. (9) therefore holds under the implicit
    no-gap condition ``dw_q <= (lambda + 2 c_j) ||x||_inf`` — true for
    dense magnitude spectra (the regime of real gradient deltas in the
    paper's experiments) but not universally.  See
    :func:`lemma1_bound_realized` for the always-valid data-dependent
    constant; both are property-tested.
    """
    hi = (1.0 - lambda_) / (2.0 * (2 ** b - 1))
    lo = lambda_ / 2.0 + (1.0 - lambda_) / (4.0 * (2 ** b - 1))
    return max(lo, hi)


def lemma1_bound_realized(lambda_: float, b: int, rho: float) -> float:
    """Corrected Lemma 1 constant given ``rho = dw_q / ||x||_inf``.

    * high-res: ``|eps| <= (1 - rho) / (2 (2^b - 1)) ||x||_inf``
      (grid radius is ``(1 - rho)||x||_inf``);
    * low-res:  ``|eps| <= max(rho / 2, lambda - rho / 2) ||x||_inf``
      (element in ``[0, lambda ||x||_inf)`` reconstructed at
      ``rho ||x||_inf / 2``).

    Reduces to eq. (9)'s low branch when ``rho == lambda`` (no gap).
    """
    hi = (1.0 - rho) / (2.0 * (2 ** b - 1))
    lo = max(rho / 2.0, lambda_ - rho / 2.0)
    return max(lo, hi)


def mixed_resolution_quantize(delta: jnp.ndarray, lambda_: float, b: int
                              ) -> QuantResult:
    """Quantize one flat vector.  Pure jnp; jit/vmap friendly."""
    x = delta.astype(jnp.float32)
    d = x.size
    absx = jnp.abs(x)
    inf = jnp.max(absx)
    safe_inf = jnp.where(inf > 0, inf, 1.0)

    hi_mask = (absx / safe_inf) >= lambda_          # eq. (6)
    dbar = jnp.sum(hi_mask)
    # smallest high-resolution magnitude = grid anchor dw_q
    dw_q = jnp.min(jnp.where(hi_mask, absx, jnp.inf))
    dw_q = jnp.where(jnp.isfinite(dw_q), dw_q, 0.0)
    r = inf - dw_q                                   # grid radius
    levels = 2 ** b - 1
    step = r / levels
    safe_step = jnp.where(step > 0, step, 1.0)

    # high-resolution reconstruction: b-bit uniform grid on [dw_q, inf]
    code = jnp.round((absx - dw_q) / safe_step)
    q_mag = dw_q + code * step                       # exact when step == 0
    hi_recon = jnp.sign(x) * q_mag

    # low-resolution reconstruction: sign bit -> +- dw_q_hat / 2 (eq. 8)
    # sign convention per eq. (7): bit 1 <=> x > 0, bit 0 <=> x <= 0.
    lo_recon = jnp.where(x > 0, dw_q / 2.0, -dw_q / 2.0)

    recon = jnp.where(hi_mask, hi_recon, lo_recon)
    recon = jnp.where(inf > 0, recon, jnp.zeros_like(x))

    s = dbar / d
    bits = d * (b * s + 1.0 - s) + _F32_BITS
    bits = jnp.where(inf > 0, bits, d + _F32_BITS)   # all-sign when zero
    aux = {"s": s, "dbar": dbar, "r": r, "dw_q": dw_q, "inf": inf}
    return QuantResult(recon=recon, bits=bits, aux=aux)


class MixedResolutionQuantizer(Quantizer):
    """Paper quantizer with per-user threshold lambda_ and bit width b."""

    name = "mixed-resolution"

    def __init__(self, lambda_: float = 0.2, b: int = 10):
        if not (0.0 <= lambda_ <= 1.0):
            raise ValueError(f"lambda_ must be in [0,1], got {lambda_}")
        if b < 2:
            raise ValueError(f"b must be >= 2, got {b}")
        self.lambda_ = float(lambda_)
        self.b = int(b)

    def __call__(self, delta, state: Any = None) -> Tuple[QuantResult, Any]:
        return mixed_resolution_quantize(delta, self.lambda_, self.b), state

    def error_bound(self) -> float:
        return lemma1_bound(self.lambda_, self.b)
