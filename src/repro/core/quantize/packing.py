"""Bit-packing for wire-format payloads (pure jnp reference).

These are the reference implementations of the packed planes the
distributed aggregation path sends over ICI collectives; the Pallas
kernels in ``repro.kernels`` implement the same transforms with VMEM
block tiling and are checked against these functions.

* sign plane — 1 bit per element, 32 elements per uint32 word;
* code plane — ``b``-bit unsigned codes packed ``32 // b`` per uint32
  (b must divide 32 for the packed path: b in {2,4,8,16}).
"""
from __future__ import annotations

import jax.numpy as jnp


def _pad_to(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    pad = (-x.shape[-1]) % multiple
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


def pack_signs(x: jnp.ndarray) -> jnp.ndarray:
    """Pack sign bits (1 <=> x > 0) of a 1-D float vector into uint32."""
    bits = (x > 0).astype(jnp.uint32)
    bits = _pad_to(bits, 32).reshape(-1, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_signs(words: jnp.ndarray, d: int) -> jnp.ndarray:
    """Inverse of pack_signs -> float32 vector of +1 / -1 (length d)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    signs = bits.reshape(-1)[:d].astype(jnp.float32) * 2.0 - 1.0
    return signs


def _check_code_width(b: int) -> None:
    # a width that does not divide 32 would silently mis-split words
    # (32 // b truncates), so both directions reject it up front
    if b < 1 or 32 % b != 0:
        raise ValueError(f"b must divide 32, got {b}")


def pack_codes(codes: jnp.ndarray, b: int) -> jnp.ndarray:
    """Pack b-bit unsigned integer codes (uint32 values < 2**b) into words."""
    _check_code_width(b)
    per = 32 // b
    codes = _pad_to(codes.astype(jnp.uint32), per).reshape(-1, per)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * b).astype(jnp.uint32)
    return jnp.sum(codes << shifts, axis=-1, dtype=jnp.uint32)


def unpack_codes(words: jnp.ndarray, b: int, n: int) -> jnp.ndarray:
    """Inverse of pack_codes -> uint32 codes (length n)."""
    _check_code_width(b)
    per = 32 // b
    shifts = (jnp.arange(per, dtype=jnp.uint32) * b).astype(jnp.uint32)
    mask = jnp.uint32(2 ** b - 1)
    codes = (words[:, None] >> shifts) & mask
    return codes.reshape(-1)[:n]
