"""The paper's power control: bisection over eta + LP feasibility (§III).

Problem (14): maximize eta s.t.  0 <= p <= 1  and for every user j

    (A_bar_j - theta_j B_bar_j) p_j - theta_j sum_{j'!=j} Btilde_j^{j'} p_j'
        >= theta_j I_M^j,          theta_j = 2^(eta b_j / B_tau) - 1.

For fixed eta the constraints are linear in p, so feasibility is an LP;
bisection over eta converges to the global optimum within eps_B
(Algorithm 1, lines 13-23).  We recover the power vector of the last
feasible eta.  scipy.optimize.linprog (HiGHS) solves the feasibility
program with objective min sum(p) — any feasible point works; minimum
total power is a natural tie-break and matches how such LPs are run in
practice.
"""
from __future__ import annotations

import numpy as np
from scipy.optimize import linprog

from ..channel.cfmmimo import ChannelRealization
from .base import PowerController, PowerSolution


def _feasible_powers(chan: ChannelRealization, theta: np.ndarray):
    """LP feasibility of (14c) for fixed theta; returns p or None."""
    K = theta.shape[0]
    # constraint rows: -(A_j - th_j B_j) p_j + th_j sum_{j'} Bt[j,j'] p_j'
    #                  <= -th_j I_M_j
    # Rows are normalized by th_j * I_M_j (RHS = -1): the raw coefficients
    # are O(1e-12) — far below the LP solver's absolute feasibility
    # tolerance, which would make every theta look "feasible".
    A_ub = theta[:, None] * chan.B_tilde.copy()
    diag = -(chan.A_bar - theta * chan.B_bar)
    A_ub[np.arange(K), np.arange(K)] = diag
    scale = theta * chan.I_M
    if np.any(scale <= 0) or not np.all(np.isfinite(A_ub)):
        return None
    A_ub = A_ub / scale[:, None]
    b_ub = -np.ones(K)
    res = linprog(c=np.ones(K), A_ub=A_ub, b_ub=b_ub,
                  bounds=[(0.0, 1.0)] * K, method="highs")
    return res.x if res.status == 0 else None


def eta_upper_bound(chan: ChannelRealization, bits: np.ndarray) -> float:
    """Upper bound on min_j rate-per-bit: every user at full power with
    zero interference — the min over users bounds the achievable min."""
    sinr_max = chan.A_bar / (chan.B_bar + chan.I_M)
    rates = chan.cfg.pre_log * np.log2(1.0 + sinr_max)
    return float(np.min(rates / np.asarray(bits, np.float64)))


class BisectionLPPowerControl(PowerController):
    """Algorithm 1's min-max-latency power control (our scheme)."""

    name = "bisection-lp"

    def __init__(self, eps_rel: float = 1e-4, max_iters: int = 60):
        self.eps_rel = float(eps_rel)
        self.max_iters = int(max_iters)

    def solve(self, chan: ChannelRealization, bits: np.ndarray
              ) -> PowerSolution:
        bits = np.asarray(bits, np.float64)
        B_tau = chan.cfg.pre_log
        lo, hi = 0.0, eta_upper_bound(chan, bits)
        eps = self.eps_rel * hi
        best_p, best_eta, iters = np.ones(chan.cfg.K), 0.0, 0
        while hi - lo > eps and iters < self.max_iters:
            iters += 1
            mid = 0.5 * (lo + hi)
            expo = mid * bits / B_tau
            if np.max(expo) > 500.0:      # 2^500: numerically infeasible
                hi = mid
                continue
            theta = np.power(2.0, expo) - 1.0
            p = _feasible_powers(chan, theta)
            if p is not None:
                lo, best_p, best_eta = mid, p, mid
            else:
                hi = mid
        return self._finish(chan, bits, best_p, eta=best_eta,
                            bisection_iters=iters)
