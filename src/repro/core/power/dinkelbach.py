"""Dinkelbach power control benchmark [21] — energy-efficiency maximizer.

maximize  EE(p) = sum_j R_j(p) / (P_c + p^u sum_j p_j)   s.t. 0<=p<=1.

Classic fractional programming: Dinkelbach's iteration solves
``max_p  N(p) - lam * D(p)`` and updates ``lam = N(p*)/D(p*)`` until the
auxiliary objective vanishes.  The inner (non-convex) subproblem is
handled by projected gradient ascent — adequate at K <= 40.
"""
from __future__ import annotations

import numpy as np

from ..channel.cfmmimo import ChannelRealization
from .base import PowerController, PowerSolution


class DinkelbachPowerControl(PowerController):
    name = "dinkelbach"

    def __init__(self, p_circuit_w: float = 0.2, outer: int = 12,
                 inner: int = 60, lr: float = 0.1, tol: float = 1e-6):
        self.p_circuit_w = float(p_circuit_w)
        self.outer, self.inner, self.lr, self.tol = outer, inner, lr, tol

    def _numer(self, chan: ChannelRealization, p: np.ndarray) -> float:
        return float(np.sum(np.log2(1.0 + chan.sinr(p))))

    def _denom(self, chan: ChannelRealization, p: np.ndarray) -> float:
        return self.p_circuit_w + chan.cfg.p_max_w * float(np.sum(p))

    def solve(self, chan: ChannelRealization, bits: np.ndarray
              ) -> PowerSolution:
        K = chan.cfg.K
        p = np.ones(K)
        lam = self._numer(chan, p) / self._denom(chan, p)
        outer_used = 0
        for _ in range(self.outer):
            outer_used += 1
            # inner: max_p numer(p) - lam * denom(p) by projected ascent
            for _ in range(self.inner):
                g = np.zeros(K)
                base = self._numer(chan, p) - lam * self._denom(chan, p)
                h = 1e-6
                for j in range(K):
                    q = p.copy()
                    q[j] = min(1.0, q[j] + h)
                    val = self._numer(chan, q) - lam * self._denom(chan, q)
                    g[j] = (val - base) / max(q[j] - p[j], 1e-12)
                p = np.clip(p + self.lr * g, 0.0, 1.0)
            f = self._numer(chan, p) - lam * self._denom(chan, p)
            lam_new = self._numer(chan, p) / self._denom(chan, p)
            if abs(f) < self.tol:
                lam = lam_new
                break
            lam = lam_new
        return self._finish(chan, bits, p, energy_efficiency=lam,
                            dinkelbach_iters=outer_used)
