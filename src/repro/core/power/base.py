"""Power-control interface.

A power controller maps (channel realization, per-user payload bits) to
an uplink power vector ``p in [0,1]^K``.  The paper's objective (eq. 11)
is to minimize the straggler latency ``max_j b_j / R_j(p)``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from ..channel.cfmmimo import ChannelRealization, uplink_latency


@dataclasses.dataclass(frozen=True)
class PowerSolution:
    p: np.ndarray              # [K] power-control coefficients in [0,1]
    rates: np.ndarray          # [K] achieved rates (bit/s)
    latencies: np.ndarray      # [K] per-user uplink latency (s)
    info: Dict[str, float]     # solver diagnostics

    @property
    def straggler_latency(self) -> float:
        return float(np.max(self.latencies))


class PowerController:
    name = "base"

    def solve(self, chan: ChannelRealization, bits: np.ndarray
              ) -> PowerSolution:
        raise NotImplementedError

    def _finish(self, chan: ChannelRealization, bits: np.ndarray,
                p: np.ndarray, **info) -> PowerSolution:
        p = np.clip(np.asarray(p, np.float64), 0.0, 1.0)
        rates = chan.rates(p)
        return PowerSolution(p=p, rates=rates,
                             latencies=uplink_latency(bits, rates),
                             info=dict(info))
