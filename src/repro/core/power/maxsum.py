"""Max-sum-rate power control benchmark [2].

maximize  sum_j log2(1 + SINR_j(p))  s.t.  0 <= p <= 1.

Non-convex; we use projected gradient ascent from full power with a
few random restarts — the standard practical approach.  Max-sum-rate
ignores per-user payloads entirely, which is exactly why it suffers
from stragglers in the paper's Table III.
"""
from __future__ import annotations

import numpy as np

from ..channel.cfmmimo import ChannelRealization
from .base import PowerController, PowerSolution


def _sum_rate(chan: ChannelRealization, p: np.ndarray) -> float:
    return float(np.sum(np.log2(1.0 + chan.sinr(p))))


def _grad(chan: ChannelRealization, p: np.ndarray, h: float = 1e-6
          ) -> np.ndarray:
    g = np.zeros_like(p)
    base = _sum_rate(chan, p)
    for j in range(p.size):
        q = p.copy()
        q[j] = min(1.0, q[j] + h)
        g[j] = (_sum_rate(chan, q) - base) / max(q[j] - p[j], 1e-12)
    return g


class MaxSumRatePowerControl(PowerController):
    name = "max-sum-rate"

    def __init__(self, iters: int = 80, lr: float = 0.1, restarts: int = 2):
        self.iters, self.lr, self.restarts = iters, lr, restarts

    def solve(self, chan: ChannelRealization, bits: np.ndarray
              ) -> PowerSolution:
        rng = np.random.default_rng(0)
        starts = [np.ones(chan.cfg.K)]
        starts += [rng.uniform(0.3, 1.0, chan.cfg.K)
                   for _ in range(self.restarts)]
        best_p, best_v = starts[0], -np.inf
        for p in starts:
            p = p.copy()
            for _ in range(self.iters):
                p = np.clip(p + self.lr * _grad(chan, p), 0.0, 1.0)
            v = _sum_rate(chan, p)
            if v > best_v:
                best_p, best_v = p, v
        return self._finish(chan, bits, best_p, sum_rate=best_v)
