"""Beyond-paper: rate-aware per-user bit allocation.

The paper fixes (lambda_j, b_j) per user and adapts powers to the
resulting bits.  The datacenter analogue (and the paper's own "future
work" direction) is the converse: given heterogeneous link rates, give
weak links a smaller high-resolution budget so every participant
finishes the round together.

Given target round latency ell*, rates R_j and the wire-format model
``bits_j(s) = d (b s + 1 - s) + 32``, solve for the per-user
high-resolution fraction:

    s_j = clip( (ell* R_j - 32 - d) / (d (b - 1)), s_min, s_max ).

Used by benchmarks/overhead.py and the latency-aware aggregation demo.
"""
from __future__ import annotations

import numpy as np


def rate_aware_fractions(rates: np.ndarray, d: int, b: int,
                         target_latency_s: float,
                         s_min: float = 0.0, s_max: float = 1.0
                         ) -> np.ndarray:
    rates = np.asarray(rates, np.float64)
    s = (target_latency_s * rates - 32.0 - d) / (d * (b - 1.0))
    return np.clip(s, s_min, s_max)


def equalizing_target_latency(rates: np.ndarray, d: int, b: int,
                              s_floor: float) -> float:
    """Smallest round latency at which every user can afford s >= s_floor."""
    bits_floor = d * (b * s_floor + 1.0 - s_floor) + 32.0
    return float(np.max(bits_floor / np.asarray(rates, np.float64)))
