"""Uplink power control: the paper's bisection+LP and both benchmarks."""
from .base import PowerController, PowerSolution
from .bisection_lp import BisectionLPPowerControl, eta_upper_bound
from .bitalloc import equalizing_target_latency, rate_aware_fractions
from .dinkelbach import DinkelbachPowerControl
from .maxsum import MaxSumRatePowerControl

POWER_CONTROLLERS = {
    "bisection-lp": BisectionLPPowerControl,
    "dinkelbach": DinkelbachPowerControl,
    "max-sum-rate": MaxSumRatePowerControl,
}


def make_power_controller(name: str, **kwargs) -> PowerController:
    if name not in POWER_CONTROLLERS:
        raise KeyError(f"unknown power controller {name!r}; "
                       f"have {list(POWER_CONTROLLERS)}")
    return POWER_CONTROLLERS[name](**kwargs)
