"""Token-stream data pipeline for the distributed-training examples:
deterministic sharded batching with host-side prefetch."""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class TokenBatcher:
    """Yields {tokens: [B, S]} batches from a flat token stream,
    deterministically, dropping the tail."""

    def __init__(self, stream: np.ndarray, batch: int, seq: int,
                 seed: int = 0):
        self.stream, self.batch, self.seq = stream, batch, seq
        self.rng = np.random.default_rng(seed)
        self.per = len(stream) // (seq + 1)

    def __iter__(self) -> Iterator[dict]:
        order = self.rng.permutation(self.per)
        for i in range(0, self.per - self.batch + 1, self.batch):
            rows = order[i:i + self.batch]
            toks = np.stack([self.stream[r * (self.seq + 1):
                                         r * (self.seq + 1) + self.seq]
                             for r in rows])
            yield {"tokens": toks.astype(np.int32)}


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Host-side background prefetch."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    sentinel = object()

    def worker():
        for item in it:
            q.put(item)
        q.put(sentinel)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is sentinel:
            return
        yield item
