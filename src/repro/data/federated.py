"""Federated partitioning: disjoint IID / non-IID (Dirichlet) shards.

The paper distributes the dataset disjointly over K users (rho_j =
|D_j| / |D|) and evaluates IID and non-IID splits.  Non-IID uses the
standard Dirichlet(alpha) label-skew construction.
"""
from __future__ import annotations

from typing import List

import numpy as np

from .synthetic import ImageDataset


def partition_iid(ds: ImageDataset, K: int, seed: int = 0
                  ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    return [np.sort(s) for s in np.array_split(idx, K)]


def partition_dirichlet(ds: ImageDataset, K: int, alpha: float = 0.3,
                        seed: int = 0, min_per_user: int = 8
                        ) -> List[np.ndarray]:
    """Label-skew non-IID split; every user gets >= min_per_user."""
    rng = np.random.default_rng(seed)
    while True:
        shards = [[] for _ in range(K)]
        for c in range(ds.n_classes):
            idx_c = np.flatnonzero(ds.y == c)
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(K, alpha))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for u, part in enumerate(np.split(idx_c, cuts)):
                shards[u].extend(part.tolist())
        if min(len(s) for s in shards) >= min_per_user:
            return [np.sort(np.asarray(s)) for s in shards]
        seed += 1
        rng = np.random.default_rng(seed)


def partition_powerlaw(ds: ImageDataset, K: int, exponent: float = 1.3,
                       seed: int = 0, min_per_user: int = 8
                       ) -> List[np.ndarray]:
    """Heterogeneous-size IID split: user j's shard size proportional to
    ``(j+1)^-exponent`` (Zipf-like device heterogeneity, as in the
    energy/latency FL-over-CFmMIMO literature), floored at
    ``min_per_user``.  Label distribution stays IID; only |D_j| varies,
    so rho_j = |D_j|/|D| and the per-user computation loads spread."""
    if len(ds) < K:
        raise ValueError(
            f"partition_powerlaw needs >= 1 sample per user: dataset has "
            f"{len(ds)} samples for K={K} users")
    rng = np.random.default_rng(seed)
    raw = (1.0 + np.arange(K)) ** (-float(exponent))
    sizes = np.maximum((raw / raw.sum() * len(ds)).astype(int),
                       min_per_user)
    # trim the largest shards until the sizes fit the dataset again;
    # len(ds) >= K guarantees the argmax shard holds >= 2 samples
    # whenever trimming is still needed, so no shard ever reaches 0
    while sizes.sum() > len(ds):
        sizes[int(np.argmax(sizes))] -= 1
    assert sizes.min() >= 1, sizes
    idx = rng.permutation(len(ds))
    cuts = np.cumsum(sizes)[:-1]
    return [np.sort(s) for s in np.split(idx[:sizes.sum()], cuts)]


def validate_shards(shards: List[np.ndarray]) -> None:
    """Refuse empty user shards loudly.  An empty shard used to surface
    as ``take=0`` reshape failures deep inside the engine's first jitted
    round; every partitioner above guarantees >= 1 sample per user, so
    hitting this means hand-built shards or a partitioner bug."""
    for j, s in enumerate(shards):
        if len(s) == 0:
            raise ValueError(
                f"user {j} has an empty data shard (0 of {len(shards)} "
                "shards' samples); every user must hold >= 1 sample — "
                "check the partitioner arguments (K vs dataset size)")


def user_fractions(shards: List[np.ndarray]) -> np.ndarray:
    """rho_j = |D_j| / |D|."""
    sizes = np.array([len(s) for s in shards], np.float64)
    return sizes / sizes.sum()


def minibatches(rng: np.random.Generator, shard: np.ndarray,
                batch_size: int, n_batches: int):
    """Sample n_batches random minibatches (with replacement across
    batches) from a user shard — the paper's xi_j <= |D_j| sampling."""
    for _ in range(n_batches):
        take = min(batch_size, len(shard))
        yield rng.choice(shard, size=take, replace=False)
