"""Synthetic datasets.

This container is offline, so CIFAR-10/100 / Fashion-MNIST cannot be
downloaded; the FL experiments instead use *structured* synthetic image
classification problems that are genuinely learnable (class-conditional
templates + per-sample deformation + noise) with the same tensor shapes
as the paper's datasets.  The learning dynamics (non-trivial accuracy
growth over FL rounds, sensitivity to quantization error) are what the
paper's tables measure; absolute accuracy values are not comparable to
the paper's and EXPERIMENTS.md reports them as such.

Also provides token streams for the language-model examples.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ImageDataset:
    x: np.ndarray          # [N, H, W, C] float32 in [0, 1]
    y: np.ndarray          # [N] int64
    n_classes: int

    def __len__(self):
        return self.x.shape[0]


def make_image_classification(n_samples: int = 10_000, hw: int = 32,
                              channels: int = 3, n_classes: int = 10,
                              noise: float = 0.35, seed: int = 0
                              ) -> ImageDataset:
    """Class-conditional low-frequency templates + jitter + noise."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float32) / hw
    templates = []
    for c in range(n_classes):
        fx, fy = rng.uniform(1.0, 4.0, 2)
        phase = rng.uniform(0, 2 * np.pi, 2)
        base = (np.sin(2 * np.pi * fx * xx + phase[0])
                * np.cos(2 * np.pi * fy * yy + phase[1]))
        chan = rng.uniform(0.3, 1.0, channels)
        templates.append(base[..., None] * chan[None, None, :])
    templates = np.stack(templates)                   # [C, H, W, ch]

    y = rng.integers(0, n_classes, n_samples)
    shifts = rng.integers(-3, 4, (n_samples, 2))
    x = np.empty((n_samples, hw, hw, channels), np.float32)
    for i in range(n_samples):
        t = np.roll(templates[y[i]], shifts[i], axis=(0, 1))
        x[i] = t + noise * rng.standard_normal(t.shape)
    x = (x - x.min()) / (x.max() - x.min() + 1e-9)
    return ImageDataset(x=x.astype(np.float32), y=y.astype(np.int64),
                        n_classes=n_classes)


# dataset registry mirroring the paper's three benchmarks
def make_dataset(name: str, n_samples: int = 10_000, seed: int = 0
                 ) -> ImageDataset:
    if name == "cifar10-syn":
        return make_image_classification(n_samples, 32, 3, 10, seed=seed)
    if name == "cifar100-syn":
        return make_image_classification(n_samples, 32, 3, 100, seed=seed)
    if name == "fashion-syn":
        return make_image_classification(n_samples, 28, 3, 10, seed=seed)
    raise KeyError(f"unknown dataset {name!r}")


def make_lm_dataset(n_samples: int = 2_048, seq_len: int = 32,
                    vocab: int = 512, seed: int = 0) -> ImageDataset:
    """Next-token-prediction windows over a Markov stream, packaged in
    the :class:`ImageDataset` container the FL stack already speaks:
    ``x`` [N, S] int64 token windows, ``y`` [N] the next token after
    each window, ``n_classes = vocab``.  This is what lets the
    pytree-generic engine federate the registry transformers through
    the same sharding/minibatching/aggregation machinery as the paper
    CNN."""
    stream = make_token_stream(n_samples + seq_len + 1, vocab, seed=seed)
    x = np.stack([stream[i:i + seq_len] for i in range(n_samples)])
    y = stream[seq_len:seq_len + n_samples].copy()
    return ImageDataset(x=x.astype(np.int64), y=y.astype(np.int64),
                        n_classes=vocab)


def make_token_stream(n_tokens: int, vocab: int, seed: int = 0,
                      order: int = 2) -> np.ndarray:
    """Markov token stream (learnable bigram structure) for LM demos."""
    rng = np.random.default_rng(seed)
    # sparse transition structure: each context prefers ~8 next tokens
    next_tokens = rng.integers(0, vocab, (vocab, 8))
    out = np.empty(n_tokens, np.int64)
    cur = int(rng.integers(0, vocab))
    for i in range(n_tokens):
        if rng.random() < 0.1:
            cur = int(rng.integers(0, vocab))
        else:
            cur = int(next_tokens[cur, rng.integers(0, 8)])
        out[i] = cur
    return out
