from .federated import (minibatches, partition_dirichlet, partition_iid,
                        partition_powerlaw, user_fractions)
from .pipeline import TokenBatcher, prefetch
from .synthetic import (ImageDataset, make_dataset,
                        make_image_classification, make_token_stream)
