"""repro.phy — batched JAX physical layer for the sweep engine.

JAX port of the CFmMIMO channel + power-control stack, vmapped over a
leading batch axis of (realization x sweep-cell x round):

* :mod:`channel` — eq. (5) coefficient bundle as a jit-friendly
  ``ChannelBatch`` pytree; batched realization drawing;
* :mod:`solvers` — bisection (projected linear-solve feasibility
  instead of scipy's LP), Dinkelbach and max-sum-rate as
  fixed-iteration lax loops, all mask-aware for user churn;
* :mod:`bitalloc` — batched rate-aware bit allocation.

The numpy implementations in ``core/channel`` and ``core/power`` are
untouched and remain the golden references; parity and tolerances are
pinned by tests/test_phy_parity.py and documented in DESIGN.md
section 7.
"""
from .bitalloc import (equalizing_target_latency_batch,
                       rate_aware_fractions_batch)
from .channel import (ChannelBatch, bundle_from_realization_grid,
                      bundle_from_realizations, compute_bundle,
                      make_channel_batch, uplink_latency_batch)
from .solvers import (BatchedPowerSolution, batched_solver,
                      bisection_solve, dinkelbach_solve,
                      eta_upper_bound_batch, maxsum_solve, maxsum_starts)

__all__ = [
    "BatchedPowerSolution", "ChannelBatch", "batched_solver",
    "bisection_solve", "bundle_from_realization_grid",
    "bundle_from_realizations", "compute_bundle",
    "dinkelbach_solve", "equalizing_target_latency_batch",
    "eta_upper_bound_batch", "make_channel_batch", "maxsum_solve",
    "maxsum_starts", "rate_aware_fractions_batch",
    "uplink_latency_batch",
]
