"""Batched JAX port of the CFmMIMO channel layer (eq. 4-5).

``ChannelBatch`` carries the eq. (5) coefficient bundle
(A_bar, B_bar, B_tilde, I_M) with an arbitrary set of leading batch
axes, registered as a jax pytree so it flows straight into jitted
solvers.  Three ways to build one:

* :func:`bundle_from_realizations` — stack numpy
  ``ChannelRealization`` objects (the golden reference path; exact,
  no re-derivation);
* :func:`compute_bundle` — the eq. (5) math in jnp given
  (beta, pilot), vmappable over leading axes;
* :func:`make_channel_batch` — draw B realizations device-side in one
  vmapped call: positions and the (sequential, data-dependent) greedy
  pilot assignment stay on the host exactly as in
  ``core.channel.make_channel``, the O(M K^2) bundle math runs batched
  on device.

The numpy layer in ``core/channel`` remains the golden reference; this
module is the production batched path (see DESIGN.md section 7).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel.cfmmimo import (CFmMIMOConfig, ChannelRealization,
                                        _greedy_pilot_assignment,
                                        draw_positions, large_scale_fading,
                                        make_channel)


@dataclasses.dataclass(frozen=True)
class ChannelBatch:
    """eq. (5) coefficient bundle with leading batch axes.

    Array fields are pytree children; the scalar network constants
    (identical across the batch by construction — one sweep scenario,
    one Table-I parameterization) ride as static aux data so jitted
    solvers specialize on them.
    """
    A_bar: jnp.ndarray        # [..., K]
    B_bar: jnp.ndarray        # [..., K]
    B_tilde: jnp.ndarray      # [..., K, K], zero diagonal
    I_M: jnp.ndarray          # [..., K]
    pre_log: float            # B_tau = B (1 - tau_p / tau_c)
    p_max_w: float            # p^u

    @property
    def K(self) -> int:
        return self.A_bar.shape[-1]

    @property
    def batch_shape(self):
        return self.A_bar.shape[:-1]

    def sinr(self, p: jnp.ndarray, mask: Optional[jnp.ndarray] = None
             ) -> jnp.ndarray:
        """eq. (5): SINR per user for power vectors p [..., K].

        ``mask`` (0/1 per user) implements the engine's sub-channel
        semantics device-side: inactive users neither transmit
        (their p is forced to 0 — no interference contributed) nor
        report a SINR (masked rows return 0).
        """
        if mask is not None:
            p = p * mask
        num = self.A_bar * p
        # B_tilde has a zero diagonal, so the matvec IS the j' != j sum
        cross = jnp.einsum("...jk,...k->...j", self.B_tilde, p)
        den = self.B_bar * p + cross + self.I_M
        out = num / den
        if mask is not None:
            out = out * mask
        return out

    def rates(self, p: jnp.ndarray, mask: Optional[jnp.ndarray] = None
              ) -> jnp.ndarray:
        """eq. (4): achievable uplink rate (bit/s) per user."""
        return self.pre_log * jnp.log2(1.0 + self.sinr(p, mask))


def _register():
    def flatten(cb):
        return ((cb.A_bar, cb.B_bar, cb.B_tilde, cb.I_M),
                (cb.pre_log, cb.p_max_w))

    def unflatten(aux, children):
        return ChannelBatch(*children, pre_log=aux[0], p_max_w=aux[1])

    jax.tree_util.register_pytree_node(ChannelBatch, flatten, unflatten)


_register()


def bundle_from_realizations(chans: Sequence[ChannelRealization]
                             ) -> ChannelBatch:
    """Stack numpy realizations into one [B, ...] device bundle."""
    if not chans:
        raise ValueError("need at least one realization")
    cfg = chans[0].cfg
    for c in chans[1:]:
        if (c.cfg.pre_log != cfg.pre_log
                or c.cfg.p_max_w != cfg.p_max_w or c.cfg.K != cfg.K):
            raise ValueError("realizations in a batch must share the "
                             "network constants (pre_log, p_max, K)")
    stack = {f: jnp.asarray(np.stack([getattr(c, f) for c in chans]))
             for f in ("A_bar", "B_bar", "B_tilde", "I_M")}
    return ChannelBatch(pre_log=cfg.pre_log, p_max_w=cfg.p_max_w, **stack)


def bundle_from_realization_grid(grid: Sequence[Sequence[ChannelRealization]]
                                 ) -> ChannelBatch:
    """Stack a [cells][R] grid of realizations into one FLAT
    [cells * R] bundle, row-major (cell-major, replicate-minor).

    The replicated sweep driver solves all cells x Monte-Carlo
    replicates of a round in one device call; solution row
    ``i * R + r`` belongs to (cell i, replicate r).  All rows must
    share the network constants — enforced by
    :func:`bundle_from_realizations`.
    """
    flat = [chan for row in grid for chan in row]
    return bundle_from_realizations(flat)


def compute_bundle(cfg: CFmMIMOConfig, beta: jnp.ndarray,
                   pilot: jnp.ndarray) -> ChannelBatch:
    """eq. (5) coefficient bundle in jnp from (beta [..., M, K],
    pilot [..., K]); mirrors ``make_channel``'s numpy math exactly
    (including the squared coherent-gain numerator — DESIGN.md
    section 3) and vmaps over any leading batch axes."""
    copilot = (pilot[..., :, None] == pilot[..., None, :]).astype(
        beta.dtype)                                       # [..., K, K]
    sigma2 = cfg.noise_w
    p_p = cfg.tau_p * cfg.p_max_w

    denom = p_p * jnp.einsum("...mj,...jk->...mk", beta, copilot) + sigma2
    gamma = p_p * beta ** 2 / denom                       # [..., M, K]

    N = float(cfg.N)
    A_bar = (N * gamma.sum(axis=-2)) ** 2                 # [..., K]
    B_bar = N * (gamma * beta).sum(axis=-2)
    I_M = N * sigma2 * gamma.sum(axis=-2) / cfg.p_max_w

    first = N * jnp.einsum("...mj,...mk->...jk", gamma, beta)
    ratio = N * jnp.einsum("...mj,...mj,...mk->...jk",
                           gamma, 1.0 / beta, beta)
    B_tilde = first + copilot * ratio ** 2
    K = beta.shape[-1]
    eye = jnp.eye(K, dtype=beta.dtype)
    B_tilde = B_tilde * (1.0 - eye)                       # j' != j sum only
    return ChannelBatch(A_bar=A_bar, B_bar=B_bar, B_tilde=B_tilde,
                        I_M=I_M, pre_log=cfg.pre_log, p_max_w=cfg.p_max_w)


def make_channel_batch(cfg: CFmMIMOConfig, seeds: Sequence[int]
                       ) -> ChannelBatch:
    """Draw B large-scale realizations as ONE batched bundle.

    Per seed this reproduces ``make_channel``'s geometry and pilot
    assignment exactly (same host RNG stream, same greedy loop); the
    coefficient math then runs as a single jitted vmap on device.
    """
    betas, pilots = [], []
    for seed in seeds:
        ap, users = draw_positions(cfg, int(seed))
        beta = large_scale_fading(cfg, ap, users)
        betas.append(beta)
        pilots.append(_greedy_pilot_assignment(beta, cfg.tau_p))
    beta_b = jnp.asarray(np.stack(betas))                 # [B, M, K]
    pilot_b = jnp.asarray(np.stack(pilots))               # [B, K]
    return jax.jit(lambda b, p: compute_bundle(cfg, b, p))(beta_b, pilot_b)


def uplink_latency_batch(bits: jnp.ndarray, rates: jnp.ndarray,
                         mask: Optional[jnp.ndarray] = None
                         ) -> jnp.ndarray:
    """eq. (12) batched; masked (absent) users contribute 0 latency so
    they never become the straggler."""
    lat = bits / jnp.maximum(rates, 1e-9)
    return lat if mask is None else lat * mask


__all__ = ["ChannelBatch", "bundle_from_realization_grid",
           "bundle_from_realizations", "compute_bundle", "make_channel",
           "make_channel_batch", "uplink_latency_batch"]
