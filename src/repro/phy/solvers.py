"""Batched, jit-compiled power-control solvers (paper section III-IV).

JAX ports of the three ``core/power`` controllers, vmapped over a
leading batch of (realization x sweep-cell x round) problems so a whole
grid's power control runs as ONE device call per round instead of one
host scipy/numpy solve per cell:

* :func:`bisection_solve` — Algorithm 1 (min-max latency).  scipy's LP
  feasibility program is replaced by a direct linear solve: for fixed
  theta the constraint set ``p >= M p + c`` (M >= 0, c > 0) is feasible
  iff the least fixed point ``p* = (I - M)^{-1} c`` exists with
  ``0 <= p* <= 1`` (a nonnegative solution certifies the spectral
  radius of M is < 1, Perron-Frobenius), and p* is exactly the LP's
  min-sum-power optimum — so the batched path reproduces the reference
  bisection trajectory decision for decision.
* :func:`dinkelbach_solve` — energy-efficiency maximizer; the outer
  Dinkelbach update and the early-exit ``|f| < tol`` break are
  replayed with a per-cell done mask inside fixed-iteration loops.
* :func:`maxsum_solve` — projected gradient ascent with restarts.

Gradient modes: ``grad_mode="fd"`` replays the numpy references'
forward-difference gradients step for step (exact-trajectory parity in
x64 — tests/test_phy_parity.py); ``"auto"`` uses jax.grad, which is the
float32 default because a 1e-6 forward difference is below f32 ulp of
the objective and would be pure noise.  ``None`` picks by the active
x64 flag.  See DESIGN.md section 7 for the tolerance contract.

Absent-user masking (``mask`` of 0/1 per user) implements the
engine's sub-channel semantics (sim/engine.py churn path): masked
users get no power, contribute no interference, are excluded from the
eta bound / objectives, and never become the straggler.

Public API / invariants:

* ``bisection_solve`` / ``dinkelbach_solve`` / ``maxsum_solve`` —
  each takes a :class:`ChannelBatch` (leading batch axis B) + per-user
  payload ``bits [B, K]`` (+ optional ``mask [B, K]``) and returns a
  ``PowerSolution``: power coefficients ``p [B, K]`` in [0, 1], the
  straggler ``latency_s [B]``, per-user completion times
  ``latencies [B, K]`` (the async event clock's input — DESIGN.md
  section 11; 0 where masked), and a solver ``info`` dict of
  convergence telemetry.
* Parity: every solver reproduces its ``core/power`` numpy reference
  within the DESIGN.md section 7 tolerance contract (exact trajectory
  in x64 with ``grad_mode="fd"``); masked-user semantics match the
  engine's sub-channel restriction exactly.
* Fixed iteration counts — no data-dependent python control flow, so
  one trace serves every batch and jit caches never churn; early
  exits are replayed with done masks inside the compiled loop.
* obs taps (``phy.solve`` records, solver info scalars) are
  trace-time gated: with no active session nothing is staged
  (DESIGN.md section 10).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs

from .channel import ChannelBatch, uplink_latency_batch


def _x64_enabled() -> bool:
    return bool(jax.config.jax_enable_x64)


def _resolve_grad_mode(grad_mode: Optional[str]) -> str:
    if grad_mode is None:
        return "fd" if _x64_enabled() else "auto"
    if grad_mode not in ("fd", "auto"):
        raise ValueError(f"unknown grad_mode {grad_mode!r}")
    return grad_mode


@dataclasses.dataclass(frozen=True)
class BatchedPowerSolution:
    """Batched counterpart of ``core.power.base.PowerSolution``."""
    p: jnp.ndarray              # [B, K] power coefficients in [0, 1]
    rates: jnp.ndarray          # [B, K] achieved rates (bit/s); 0 if masked
    latencies: jnp.ndarray      # [B, K] uplink latency (s); 0 if masked
    info: Dict[str, jnp.ndarray]  # per-cell solver diagnostics [B]

    @property
    def straggler_latency(self) -> jnp.ndarray:
        return jnp.max(self.latencies, axis=-1)       # [B]


def _ones_mask(cb: ChannelBatch, bits: jnp.ndarray) -> jnp.ndarray:
    shape = jnp.broadcast_shapes(cb.A_bar.shape, bits.shape)
    return jnp.ones(shape, dtype=cb.A_bar.dtype)


def _finish(cb: ChannelBatch, bits: jnp.ndarray, mask: jnp.ndarray,
            p: jnp.ndarray, info: Dict[str, jnp.ndarray]
            ) -> BatchedPowerSolution:
    p = jnp.clip(p, 0.0, 1.0) * mask
    rates = cb.rates(p, mask)
    lat = uplink_latency_batch(bits, rates, mask)
    return BatchedPowerSolution(p=p, rates=rates * mask, latencies=lat,
                                info=info)


def _normalize(cb: ChannelBatch) -> ChannelBatch:
    """Scale each user's coefficient row by 1 / I_M_j.

    SINR_j is invariant under a common scaling of
    (A_bar_j, B_bar_j, B_tilde[j, :], I_M_j), and the raw Table-I
    coefficients sit at ~1e-19: fine in f64, but the f32 autodiff
    backward pass squares the SINR denominator (~1e-20 -> underflows to
    0 -> NaN).  Normalized rows are O(1)-O(100) and f32-safe; the
    numpy LP reference applies the same row normalization for the same
    reason (bisection_lp.py).
    """
    s = 1.0 / cb.I_M
    return ChannelBatch(A_bar=cb.A_bar * s, B_bar=cb.B_bar * s,
                        B_tilde=cb.B_tilde * s[..., :, None],
                        I_M=jnp.ones_like(cb.I_M),
                        pre_log=cb.pre_log, p_max_w=cb.p_max_w)


def _sum_rate_obj(cb: ChannelBatch, mask: jnp.ndarray
                  ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """sum_j log2(1 + SINR_j) over active users; p [..., K] -> [...]."""
    def obj(p):
        return jnp.sum(mask * jnp.log2(1.0 + cb.sinr(p, mask)), axis=-1)
    return obj


def _fd_grad(obj: Callable, p: jnp.ndarray, mask: jnp.ndarray,
             h: float = 1e-6) -> jnp.ndarray:
    """The numpy references' forward difference, replayed exactly:
    q_j = min(1, p_j + h), g_j = (obj(q) - obj(p)) / max(q_j - p_j,
    1e-12), one shared base evaluation per call.

    The base point rides through the SAME vmapped evaluation as the
    perturbations (extra row 0): when a coordinate is clipped
    (q_j == p_j bitwise) the difference must be exactly 0, as it is in
    the scalar numpy path — evaluating the base through a separately
    compiled graph can differ by an ulp, and the 1e-12 denominator
    floor would amplify that into a phantom 1e-3 gradient.
    """
    K = p.shape[-1]
    eye = jnp.eye(K, dtype=bool)
    q = jnp.where(eye, jnp.minimum(1.0, p[..., None, :] + h),
                  p[..., None, :])                   # [..., Kpert, K]
    q_aug = jnp.concatenate([p[..., None, :], q], axis=-2)
    vals_aug = jax.vmap(obj, in_axes=-2, out_axes=-1)(q_aug)
    base, vals = vals_aug[..., 0], vals_aug[..., 1:]  # [...], [..., Kpert]
    qdiag = jnp.minimum(1.0, p + h)
    g = (vals - base[..., None]) / jnp.maximum(qdiag - p, 1e-12)
    # a clipped coordinate (q_j == p_j, i.e. p_j == 1) has difference
    # EXACTLY 0 in the scalar reference; batched gemm rounding is
    # positional, so enforce the zero structurally instead of trusting
    # val == base bitwise across rows
    g = jnp.where(qdiag > p, g, 0.0)
    return g * mask


def _auto_grad(obj: Callable, p: jnp.ndarray, mask: jnp.ndarray
               ) -> jnp.ndarray:
    return jax.grad(lambda q: jnp.sum(obj(q)))(p) * mask


def _grad_fn(grad_mode: str) -> Callable:
    return _fd_grad if grad_mode == "fd" else _auto_grad


# ------------------------------------------------------------ eta bound
def eta_upper_bound_batch(cb: ChannelBatch, bits: jnp.ndarray,
                          mask: Optional[jnp.ndarray] = None
                          ) -> jnp.ndarray:
    """Batched ``core.power.eta_upper_bound``: per-cell upper bound on
    the min rate-per-bit (full power, zero interference)."""
    if mask is None:
        mask = _ones_mask(cb, bits)
    sinr_max = cb.A_bar / (cb.B_bar + cb.I_M)
    rates = cb.pre_log * jnp.log2(1.0 + sinr_max)
    per_user = jnp.where(mask > 0, rates / bits, jnp.inf)
    return jnp.min(per_user, axis=-1)                # [B]


# --------------------------------------------------------- bisection-LP
@partial(jax.jit, static_argnames=("max_iters",))
@_obs.retrace_probe("phy.bisection_core")
def _bisection_core(cb: ChannelBatch, bits, mask, eps_rel, max_iters):
    B_tau = cb.pre_log
    K = cb.K
    hi0 = eta_upper_bound_batch(cb, bits, mask)      # [B]
    eps = eps_rel * hi0
    lo0 = jnp.zeros_like(hi0)
    eye = jnp.eye(K, dtype=cb.A_bar.dtype)

    def feasible_point(theta):
        """Least fixed point of p = M p + c on the active sub-channel;
        (p*, feasible) — feasible iff p* is finite and inside the box
        (and every active user's SINR target is attainable at all:
        A_bar_j - theta_j B_bar_j > 0)."""
        denom = cb.A_bar - theta * cb.B_bar          # [B, K]
        bad = jnp.any((mask > 0) & (denom <= 0), axis=-1)
        safe = jnp.where(denom > 0, denom, 1.0)
        row = theta / safe * mask                    # [B, K]
        M = row[..., :, None] * cb.B_tilde * mask[..., None, :]
        c = row * cb.I_M                             # [B, K]
        p_star = jnp.linalg.solve(eye - M, c[..., None])[..., 0]
        finite = jnp.all(jnp.isfinite(p_star), axis=-1)
        inbox = jnp.all((p_star >= 0.0) & (p_star <= 1.0), axis=-1)
        return p_star, finite & inbox & ~bad

    def cond(state):
        lo, hi, best_p, best_eta, iters = state
        return jnp.any((hi - lo > eps) & (iters < max_iters))

    def body(state):
        lo, hi, best_p, best_eta, iters = state
        run = (hi - lo > eps) & (iters < max_iters)  # [B]
        iters = iters + run.astype(iters.dtype)
        mid = 0.5 * (lo + hi)
        expo = mid[..., None] * bits / B_tau         # [B, K]
        expo_max = jnp.max(jnp.where(mask > 0, expo, -jnp.inf), axis=-1)
        skip = expo_max > 500.0                      # 2^500: infeasible
        theta = jnp.exp2(jnp.minimum(expo, 500.0)) - 1.0
        p_star, ok = feasible_point(theta)
        feas = run & ok & ~skip
        infeas = run & ~(ok & ~skip)
        lo = jnp.where(feas, mid, lo)
        best_eta = jnp.where(feas, mid, best_eta)
        best_p = jnp.where(feas[..., None], p_star, best_p)
        hi = jnp.where(infeas, mid, hi)
        return lo, hi, best_p, best_eta, iters

    state0 = (lo0, hi0, jnp.broadcast_to(mask, bits.shape),
              jnp.zeros_like(hi0), jnp.zeros_like(hi0, dtype=jnp.int32))
    lo, hi, best_p, best_eta, iters = jax.lax.while_loop(cond, body,
                                                         state0)
    # convergence state for telemetry/diagnostics: a cell that still had
    # gap > eps when the shared loop stopped hit max_iters
    return best_p, {"eta": best_eta,
                    "bisection_iters": iters.astype(bits.dtype),
                    "bisection_gap": hi - lo,
                    "bisection_converged": (hi - lo) <= eps}


def bisection_solve(cb: ChannelBatch, bits: jnp.ndarray,
                    mask: Optional[jnp.ndarray] = None,
                    eps_rel: float = 1e-4, max_iters: int = 60
                    ) -> BatchedPowerSolution:
    """Batched Algorithm 1: bisection over eta with a projected
    linear-solve feasibility oracle (replaces the reference's scipy
    LP; same decisions, same returned min-sum-power vector)."""
    bits = jnp.asarray(bits)
    mask = _ones_mask(cb, bits) if mask is None else jnp.asarray(mask)
    bits = jnp.broadcast_to(bits, mask.shape)
    p, info = _bisection_core(cb, bits, mask, jnp.asarray(eps_rel),
                              int(max_iters))
    return _finish(cb, bits, mask, p, info)


# ----------------------------------------------------------- dinkelbach
@partial(jax.jit, static_argnames=("outer", "inner", "grad_mode"))
@_obs.retrace_probe("phy.dinkelbach_core")
def _dinkelbach_core(cb: ChannelBatch, bits, mask, p_circuit_w, lr, tol,
                     outer, inner, grad_mode):
    grad = _grad_fn(grad_mode)
    numer = _sum_rate_obj(_normalize(cb), mask)

    def denom(p):
        return p_circuit_w + cb.p_max_w * jnp.sum(p * mask, axis=-1)

    p0 = mask * 1.0
    lam0 = numer(p0) / denom(p0)

    def outer_step(carry, _):
        p, lam, p_best, lam_best, done, used, f_last, safeguard = carry

        # inner: max_p numer(p) - lam * denom(p) by projected ascent
        # (lam is [B]; the FD perturbation axis is vmapped out, so q
        # arrives here with the same rank as p and lam broadcasts)
        def obj(q):
            return numer(q) - lam * denom(q)

        def ascent(_, pp):
            g = grad(obj, pp, mask)
            return jnp.clip(pp + lr * g, 0.0, 1.0)

        p_new = jax.lax.fori_loop(0, inner, ascent, p)
        p = jnp.where(done[..., None], p, p_new)
        f = numer(p) - lam * denom(p)
        lam_new = numer(p) / denom(p)
        used = used + jnp.where(done, 0.0, 1.0)
        lam = jnp.where(done, lam, lam_new)
        # safeguard: track the best-EE iterate.  The projected-ascent
        # inner solve is inexact, so the raw lambda sequence need not be
        # monotone (it is frozen in fd parity mode, where the reference
        # never escapes the all-ones clip); reporting the running best
        # keeps Dinkelbach's monotone-EE contract without touching the
        # reference trajectory.
        improved = ~done & (lam_new > lam_best)
        p_best = jnp.where(improved[..., None], p, p_best)
        lam_best = jnp.where(improved, lam_new, lam_best)
        # diagnostics (read-only w.r.t. the p/lam trajectory): the last
        # Dinkelbach residual |f| before convergence and how often the
        # best-iterate safeguard had to reject a non-improving step
        f_last = jnp.where(done, f_last, jnp.abs(f))
        safeguard = safeguard + jnp.where(~done & ~improved, 1.0, 0.0)
        done = done | (~done & (jnp.abs(f) < tol))
        return (p, lam, p_best, lam_best, done, used, f_last,
                safeguard), lam_best

    carry0 = (p0, lam0, p0, lam0, jnp.zeros(lam0.shape, dtype=bool),
              jnp.zeros_like(lam0), jnp.full_like(lam0, jnp.inf),
              jnp.zeros_like(lam0))
    (_, _, p_best, lam_best, done, used, f_last, safeguard), trace = \
        jax.lax.scan(outer_step, carry0, None, length=outer)
    info = {"energy_efficiency": lam_best, "dinkelbach_iters": used,
            "dinkelbach_converged": done,
            "dinkelbach_residual": f_last,
            "dinkelbach_safeguard": safeguard,
            "ee_trace": jnp.moveaxis(trace, 0, -1)}  # [B, outer]
    return p_best, info


def dinkelbach_solve(cb: ChannelBatch, bits: jnp.ndarray,
                     mask: Optional[jnp.ndarray] = None,
                     p_circuit_w: float = 0.2, outer: int = 12,
                     inner: int = 60, lr: float = 0.1, tol: float = 1e-6,
                     grad_mode: Optional[str] = None
                     ) -> BatchedPowerSolution:
    """Batched Dinkelbach energy-efficiency maximizer; replays the
    reference's outer update and early-exit break with a per-cell done
    mask.  ``info["ee_trace"]`` holds the per-outer-iteration lambda
    (frozen after convergence) for the monotonicity property test."""
    bits = jnp.asarray(bits)
    mask = _ones_mask(cb, bits) if mask is None else jnp.asarray(mask)
    bits = jnp.broadcast_to(bits, mask.shape)
    p, info = _dinkelbach_core(cb, bits, mask, jnp.asarray(p_circuit_w),
                               jnp.asarray(lr), jnp.asarray(tol),
                               int(outer), int(inner),
                               _resolve_grad_mode(grad_mode))
    return _finish(cb, bits, mask, p, info)


# -------------------------------------------------------- max-sum-rate
def maxsum_starts(mask_np: np.ndarray, restarts: int) -> np.ndarray:
    """Reference start points per cell: full power + ``restarts`` draws
    of default_rng(0).uniform(0.3, 1, K_active) scattered onto the
    active coordinates — matching MaxSumRatePowerControl.solve on the
    corresponding sub-channel."""
    mask_np = np.asarray(mask_np, np.float64)
    B, K = mask_np.shape
    out = np.zeros((B, restarts + 1, K))
    for i in range(B):
        idx = np.flatnonzero(mask_np[i])
        rng = np.random.default_rng(0)
        out[i, 0, idx] = 1.0
        for r in range(restarts):
            out[i, 1 + r, idx] = rng.uniform(0.3, 1.0, len(idx))
    return out


def _expand(cb: ChannelBatch, mask: jnp.ndarray):
    """Insert a restart axis after the batch axis."""
    e = ChannelBatch(A_bar=cb.A_bar[..., None, :],
                     B_bar=cb.B_bar[..., None, :],
                     B_tilde=cb.B_tilde[..., None, :, :],
                     I_M=cb.I_M[..., None, :],
                     pre_log=cb.pre_log, p_max_w=cb.p_max_w)
    return e, mask[..., None, :]


@partial(jax.jit, static_argnames=("iters", "grad_mode"))
@_obs.retrace_probe("phy.maxsum_core")
def _maxsum_core(cb: ChannelBatch, mask, starts, lr, iters, grad_mode):
    grad = _grad_fn(grad_mode)
    cbn = _normalize(cb)
    cb_e, mask_e = _expand(cbn, mask)
    obj = _sum_rate_obj(cb_e, mask_e)

    def ascent(_, p):
        return jnp.clip(p + lr * grad(obj, p, mask_e), 0.0, 1.0)

    p_fin = jax.lax.fori_loop(0, iters, ascent, starts)  # [B, R, K]
    v = obj(p_fin)                                       # [B, R]
    best = jnp.argmax(v, axis=-1)                        # first max wins
    p_best = jnp.take_along_axis(p_fin, best[..., None, None],
                                 axis=-2)[..., 0, :]
    # first-order stationarity of the winning restart (diagnostic only;
    # computed at p_best, so the ascent trajectory is untouched)
    g_best = grad(_sum_rate_obj(cbn, mask), p_best, mask)
    return p_best, {"sum_rate": jnp.max(v, axis=-1),
                    "maxsum_grad_norm":
                        jnp.linalg.norm(g_best, axis=-1),
                    "maxsum_iters":
                        jnp.full(v.shape[:-1], float(iters),
                                 dtype=starts.dtype)}


def maxsum_solve(cb: ChannelBatch, bits: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None, iters: int = 80,
                 lr: float = 0.1, restarts: int = 2,
                 starts: Optional[np.ndarray] = None,
                 grad_mode: Optional[str] = None) -> BatchedPowerSolution:
    """Batched max-sum-rate projected gradient ascent with restarts."""
    bits = jnp.asarray(bits)
    mask = _ones_mask(cb, bits) if mask is None else jnp.asarray(mask)
    bits = jnp.broadcast_to(bits, mask.shape)
    if starts is None:
        starts = maxsum_starts(np.asarray(mask), restarts)
    p, info = _maxsum_core(cb, mask, jnp.asarray(starts),
                           jnp.asarray(lr), int(iters),
                           _resolve_grad_mode(grad_mode))
    return _finish(cb, bits, mask, p, info)


# ------------------------------------------------------------- registry
def batched_solver(controller) -> Callable:
    """Map a numpy PowerController instance to its batched counterpart,
    honoring the instance's hyper-parameters.  Returns
    ``solve(cb, bits, mask=None) -> BatchedPowerSolution``."""
    name = controller.name
    if name == "bisection-lp":
        return partial(bisection_solve, eps_rel=controller.eps_rel,
                       max_iters=controller.max_iters)
    if name == "dinkelbach":
        return partial(dinkelbach_solve,
                       p_circuit_w=controller.p_circuit_w,
                       outer=controller.outer, inner=controller.inner,
                       lr=controller.lr, tol=controller.tol)
    if name == "max-sum-rate":
        return partial(maxsum_solve, iters=controller.iters,
                       lr=controller.lr, restarts=controller.restarts)
    raise KeyError(f"no batched solver for power controller {name!r}")


__all__ = ["BatchedPowerSolution", "batched_solver", "bisection_solve",
           "dinkelbach_solve", "eta_upper_bound_batch", "maxsum_solve",
           "maxsum_starts"]
