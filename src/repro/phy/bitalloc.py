"""Batched rate-aware bit allocation (jnp port of core.power.bitalloc).

Same closed forms, vmapped over arbitrary leading axes so a whole sweep
grid's per-user high-resolution budgets come out of one device call.
The numpy originals stay the golden reference.
"""
from __future__ import annotations

import jax.numpy as jnp


def rate_aware_fractions_batch(rates: jnp.ndarray, d: int, b: int,
                               target_latency_s,
                               s_min: float = 0.0, s_max: float = 1.0
                               ) -> jnp.ndarray:
    """s_j = clip((ell* R_j - 32 - d) / (d (b - 1)), s_min, s_max);
    ``target_latency_s`` may be scalar or [..., 1] for per-cell
    targets."""
    rates = jnp.asarray(rates)
    s = (target_latency_s * rates - 32.0 - d) / (d * (b - 1.0))
    return jnp.clip(s, s_min, s_max)


def equalizing_target_latency_batch(rates: jnp.ndarray, d: int, b: int,
                                    s_floor: float) -> jnp.ndarray:
    """Smallest round latency at which every user of each cell can
    afford s >= s_floor; reduces the trailing user axis."""
    bits_floor = d * (b * s_floor + 1.0 - s_floor) + 32.0
    return jnp.max(bits_floor / jnp.asarray(rates), axis=-1)


__all__ = ["equalizing_target_latency_batch", "rate_aware_fractions_batch"]
