"""Checkpointing: pytree save/restore as a single .npz + structure map.

No orbax in this container; this implementation is complete for
single-process use (atomic write via temp file + rename, step
retention, metadata).  Sharded arrays are pulled to host before save.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    arrays = _flatten_with_paths(tree)
    meta = {"step": step, "metadata": metadata or {}}
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz")
    os.close(fd)
    np.savez(tmp, __meta__=json.dumps(meta), **arrays)
    final = os.path.join(directory, f"ckpt_{step:08d}.npz")
    os.replace(tmp, final)
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int):
    ckpts = sorted(f for f in os.listdir(directory)
                   if f.startswith("ckpt_") and f.endswith(".npz"))
    for f in ckpts[:-keep]:
        os.remove(os.path.join(directory, f))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(f for f in os.listdir(directory)
                   if f.startswith("ckpt_") and f.endswith(".npz"))
    if not ckpts:
        return None
    return int(ckpts[-1][5:-4])


def restore_checkpoint(directory: str, template: Any,
                       step: Optional[int] = None):
    """Restore into the structure of ``template``; returns
    (tree, step, metadata)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    meta = json.loads(str(data["__meta__"]))
    flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_template:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, meta["step"], meta["metadata"]
