"""Checkpointing: pytree save/restore as a single .npz + structure map.

No orbax in this container; this implementation is complete for
single-process use (atomic write via temp file + rename, step
retention, metadata).  Sharded arrays are pulled to host before save.

Restore is hardened against on-disk decay (DESIGN.md §14): a
truncated or corrupt archive (bad zip, unreadable entry), a missing
``__meta__`` word, or a shape/dtype mismatch against the template
makes ``restore_checkpoint`` fall back to the next-newest retained
checkpoint with a warning instead of raising — a crash mid-
``os.replace`` or a flipped block on disk costs at most ``keep - 1``
steps of progress, never the run.  An explicitly requested ``step``
never falls back (the caller named a file; silently handing back a
different one would be worse than the error), and when NO retained
checkpoint is valid the error from the newest candidate propagates.
"""
from __future__ import annotations

import json
import os
import tempfile
import warnings
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    arrays = _flatten_with_paths(tree)
    meta = {"step": step, "metadata": metadata or {}}
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz")
    os.close(fd)
    np.savez(tmp, __meta__=json.dumps(meta), **arrays)
    final = os.path.join(directory, f"ckpt_{step:08d}.npz")
    os.replace(tmp, final)
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int):
    ckpts = sorted(f for f in os.listdir(directory)
                   if f.startswith("ckpt_") and f.endswith(".npz"))
    for f in ckpts[:-keep]:
        os.remove(os.path.join(directory, f))


def _retained_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    ckpts = sorted(f for f in os.listdir(directory)
                   if f.startswith("ckpt_") and f.endswith(".npz"))
    return [int(f[5:-4]) for f in ckpts]


def latest_step(directory: str) -> Optional[int]:
    steps = _retained_steps(directory)
    return steps[-1] if steps else None


def _read_checkpoint(path: str, template: Any):
    """Load + validate one archive against the template; raises on any
    corruption symptom (bad zip, missing ``__meta__`` or leaf entry,
    shape/dtype mismatch) — the fallback loop's per-candidate probe."""
    data = np.load(path)
    if "__meta__" not in data:
        raise ValueError(f"checkpoint {path} has no __meta__ entry "
                         "(truncated or foreign archive)")
    meta = json.loads(str(data["__meta__"]))
    flat_template, _ = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_template:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        want = getattr(leaf, "dtype", None)
        if want is not None and arr.dtype != np.dtype(want):
            raise ValueError(f"dtype mismatch for {key}: "
                             f"{arr.dtype} vs {np.dtype(want)}")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, meta["step"], meta["metadata"]


def restore_checkpoint(directory: str, template: Any,
                       step: Optional[int] = None):
    """Restore into the structure of ``template``; returns
    (tree, step, metadata).

    With ``step=None`` the newest VALID retained checkpoint wins: a
    candidate that fails to load (corrupt/truncated archive, missing
    entries, shape/dtype drift against the template) is skipped with a
    warning and the next-newest is tried; the newest candidate's error
    re-raises only when every retained step is bad.  An explicit
    ``step`` is an exact request — no fallback, errors propagate.
    """
    if step is not None:
        return _read_checkpoint(
            os.path.join(directory, f"ckpt_{step:08d}.npz"), template)
    steps = _retained_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    first_error: Optional[BaseException] = None
    ordered = list(reversed(steps))
    for n, s in enumerate(ordered):
        path = os.path.join(directory, f"ckpt_{s:08d}.npz")
        try:
            return _read_checkpoint(path, template)
        except Exception as e:
            if first_error is None:
                first_error = e
            if n + 1 < len(ordered):
                warnings.warn(
                    f"checkpoint {path} unreadable ({e}); falling "
                    "back to the next-newest retained step",
                    stacklevel=2)
    raise first_error
