"""Adam (bias-corrected), fp32 moments."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer


def adam(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mu": z, "nu": jax.tree_util.tree_map(jnp.copy, z),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        c = state["count"] + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2)
            * jnp.square(g.astype(jnp.float32)), state["nu"], grads)
        mu_hat = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** c), mu)
        nu_hat = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** c), nu)
        updates = jax.tree_util.tree_map(
            lambda m, v: -lr * m / (jnp.sqrt(v) + eps), mu_hat, nu_hat)
        if weight_decay and params is not None:
            updates = jax.tree_util.tree_map(
                lambda u, p: u - lr * weight_decay
                * p.astype(jnp.float32), updates, params)
        return updates, {"mu": mu, "nu": nu, "count": c}

    return Optimizer(init=init, update=update)
