"""AdaGrad — the paper's local optimizer (eq. 2).

    g_acc <- g_acc + grad * grad
    w     <- w - alpha / sqrt(g_acc + eps) * grad
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer


def adagrad(alpha: float = 0.01, eps: float = 1e-8) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params=None):
        new_state = jax.tree_util.tree_map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)), state, grads)
        updates = jax.tree_util.tree_map(
            lambda g, a: -alpha * g.astype(jnp.float32)
            / jnp.sqrt(a + eps), grads, new_state)
        return updates, new_state

    return Optimizer(init=init, update=update)
