from .adagrad import adagrad
from .adam import adam
from .base import Optimizer, apply_updates
from .sgd import sgd

OPTIMIZERS = {"adagrad": adagrad, "adam": adam, "sgd": sgd}


def make_optimizer(name: str, **kwargs) -> Optimizer:
    if name not in OPTIMIZERS:
        raise KeyError(f"unknown optimizer {name!r}; have {list(OPTIMIZERS)}")
    return OPTIMIZERS[name](**kwargs)
