"""SGD with optional momentum."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer


def sgd(lr: float = 0.01, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return None
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params=None):
        if momentum == 0.0:
            return jax.tree_util.tree_map(
                lambda g: -lr * g.astype(jnp.float32), grads), None
        new_state = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        updates = jax.tree_util.tree_map(lambda m: -lr * m, new_state)
        return updates, new_state

    return Optimizer(init=init, update=update)
