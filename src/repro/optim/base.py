"""Minimal optax-free optimizer interface (pytree-native)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]                    # params -> state
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]
    # (grads, state, params) -> (updates, new_state); caller applies
    # params + updates.


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates)
