"""repro.obs core — structured, jit-safe telemetry (DESIGN.md §10).

One event = one flat JSON object.  Common envelope:

    ts      float   host wall-clock (time.time()) at emission
    kind    str     "event" | "phase" | "jit" | "counter" | "retrace"
                    | "session"
    name    str     dotted event name ("engine.round", "phy.solve", ...)
    ...             scalar payload fields + the active context tags

Sinks: an in-memory list (``ObsSession.events``, for tests and
programmatic consumers) and a JSONL file (one event per line — what
``python -m repro.obs.report`` renders).

The jit-safety contract, in one paragraph: host-side emission
(:func:`record`, :func:`counter`, ``trace.scope``) never touches device
state.  In-jit emission (:func:`jit_tap`) is gated at TRACE time — if
no session with ``jit_stream=True`` is active when the surrounding
function is traced, *nothing* is staged and the compiled program is
bit-identical to uninstrumented code (zero extra ops, zero extra
dispatches; asserted by tests/test_obs.py).  When a session IS active
at trace time, each tap site stages exactly one ``jax.debug.callback``
whose values stream to the host off the hot path (no blocking
round-trip inside the step); delivery re-resolves the active session
when the compiled step actually runs, so a step traced under one
session keeps reporting to whichever session drives later runs (and
drops events when none is active).

Public API / invariants:

* ``session(jsonl=..., memory=..., jit_stream=..., profile_round=...)``
  — the one entry point; everything else is a no-op without it.
* Emission: ``record(name, **fields)`` (host scalars),
  ``counter(name, n)`` (accumulated, flushed once at close),
  ``jit_tap(name, values)`` (in-jit, trace-time gated),
  ``enabled()`` / ``jit_stream_enabled()`` (the gates).
* Invariant 1 — zero cost when off: no active session means no staged
  ops, no host callbacks, no allocations beyond one attribute check
  per call site.
* Invariant 2 — never blocks the hot path: in-jit taps use
  ``ordered=False`` callbacks; phase scopes (repro.obs.trace) do the
  blocking at phase boundaries instead.
* Invariant 3 — the stream never holds a full tensor: array payloads
  are scalarized (0-d -> item, size <= 64 -> list, larger ->
  min/max/mean/size summary).
* Consumers: ``python -m repro.obs.report`` renders a trace
  (per-round table, phase breakdown, wire traffic, async rounds,
  retraces); sessions nest via the module-level active-session slot
  under ``_LOCK``.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

_LOCK = threading.Lock()
_ACTIVE: Optional["ObsSession"] = None
_MISSING = object()


def active_session() -> Optional["ObsSession"]:
    return _ACTIVE


def enabled() -> bool:
    """True iff an obs session is currently active."""
    return _ACTIVE is not None


def jit_stream_enabled() -> bool:
    """True iff an active session accepts in-jit taps (trace-time gate
    of :func:`jit_tap`)."""
    return _ACTIVE is not None and _ACTIVE.jit_stream


# ----------------------------------------------------------------- sinks
class MemorySink:
    """Append events to a plain list (``ObsSession.events``)."""

    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass


class JsonlSink:
    """One JSON object per line; the report CLI's input format."""

    def __init__(self, path: str) -> None:
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")

    def emit(self, event: Dict[str, Any]) -> None:
        self._f.write(json.dumps(event) + "\n")

    def close(self) -> None:
        self._f.flush()
        self._f.close()


def _scalar(v: Any) -> Any:
    """JSON-ready view of a payload value: python scalars pass through,
    0-d arrays become scalars, small arrays become lists, large arrays
    are summarized (events are telemetry, not checkpoints)."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    a = np.asarray(v)
    if a.ndim == 0:
        return a.item()
    if a.size <= 64:
        return a.tolist()
    return {"min": float(a.min()), "max": float(a.max()),
            "mean": float(a.mean()), "size": int(a.size)}


# --------------------------------------------------------------- session
class ObsSession:
    """One telemetry session: sinks + context tags + counters.

    ``profile_round`` arms a ``jax.profiler`` trace capture around that
    round (started/stopped by ``trace.round_scope``); ``retrace_storm``
    is the per-session retrace count at which a step function is
    flagged as a silent retrace storm (``storm: true`` on the retrace
    event).
    """

    def __init__(self, jsonl: Optional[str] = None, memory: bool = True,
                 jit_stream: bool = True,
                 profile_round: Optional[int] = None,
                 profile_dir: str = "runs/profile",
                 retrace_storm: int = 3) -> None:
        self.sinks: List[Any] = []
        self.memory = MemorySink() if memory else None
        if self.memory is not None:
            self.sinks.append(self.memory)
        self.jsonl_path = jsonl
        if jsonl:
            self.sinks.append(JsonlSink(jsonl))
        if not self.sinks:
            raise ValueError("session needs a sink: jsonl= or memory=True")
        self.jit_stream = jit_stream
        self.profile_round = profile_round
        self.profile_dir = profile_dir
        self.retrace_storm = retrace_storm
        self.tags: Dict[str, Any] = {}
        self.counters: Dict[str, float] = {}
        # per-session retrace counts (trace.retrace_probe fills these;
        # the global counts in repro.obs.trace survive across sessions)
        self.retraces: Dict[str, int] = {}
        self.profiling = False

    @property
    def events(self) -> List[Dict[str, Any]]:
        if self.memory is None:
            raise ValueError("session was opened with memory=False")
        return self.memory.events

    def emit(self, kind: str, name: str, **fields: Any) -> None:
        event: Dict[str, Any] = {"ts": time.time(), "kind": kind,
                                 "name": name}
        for k, v in self.tags.items():
            event[k] = _scalar(v)
        for k, v in fields.items():
            event[k] = _scalar(v)
        with _LOCK:
            for sink in self.sinks:
                sink.emit(event)

    def close(self) -> None:
        for cname in sorted(self.counters):
            self.emit("counter", cname, total=self.counters[cname])
        for name in sorted(self.retraces):
            self.emit("retrace", name, count=self.retraces[name],
                      final=True,
                      storm=self.retraces[name] >= self.retrace_storm)
        self.emit("session", "end")
        for sink in self.sinks:
            sink.close()


@contextlib.contextmanager
def session(jsonl: Optional[str] = None, memory: bool = True,
            jit_stream: bool = True, profile_round: Optional[int] = None,
            profile_dir: str = "runs/profile", retrace_storm: int = 3):
    """Activate an obs session for the dynamic extent of the block.

    Only one session may be active at a time (the global is what makes
    instrumented library code zero-config).  Enter the session BEFORE
    the instrumented jitted steps are first traced — jit taps are a
    trace-time decision (see module docstring).
    """
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("an obs session is already active; nest "
                           "obs.context() instead of obs.session()")
    sess = ObsSession(jsonl=jsonl, memory=memory, jit_stream=jit_stream,
                      profile_round=profile_round,
                      profile_dir=profile_dir,
                      retrace_storm=retrace_storm)
    _ACTIVE = sess
    sess.emit("session", "start", jit_stream=jit_stream,
              jsonl=jsonl or "")
    try:
        yield sess
    finally:
        try:
            sess.close()
        finally:
            _ACTIVE = None


# ------------------------------------------------------------- host API
def record(name: str, **fields: Any) -> None:
    """Host-side event emission; no-op without an active session."""
    sess = _ACTIVE
    if sess is not None:
        sess.emit("event", name, **fields)


def counter(name: str, value: float = 1.0) -> None:
    """Accumulate into a named session counter (flushed as one
    ``kind: counter`` event per name when the session closes)."""
    sess = _ACTIVE
    if sess is not None:
        sess.counters[name] = sess.counters.get(name, 0.0) + float(value)


@contextlib.contextmanager
def context(**tags: Any):
    """Attach tags (scenario / quantizer / round / ...) to every event
    emitted inside the block, including jit-tap deliveries that land
    while the tagged computation runs."""
    sess = _ACTIVE
    if sess is None:
        yield
        return
    old = {k: sess.tags.get(k, _MISSING) for k in tags}
    sess.tags.update(tags)
    try:
        yield
    finally:
        for k, v in old.items():
            if v is _MISSING:
                sess.tags.pop(k, None)
            else:
                sess.tags[k] = v


# ------------------------------------------------------------ in-jit API
def jit_tap(name: str, values: Dict[str, Any], **tags: Any) -> None:
    """Stream values out of jit-traced code via ``jax.debug.callback``.

    Call from inside a function that will be (or is being) jit-traced.
    Gated at trace time: without an active ``jit_stream`` session this
    stages NOTHING — the compiled program is bit-identical to the
    uninstrumented one.  With one, the callback delivers the values to
    whatever session is active when the compiled step executes
    (dropped if none), so recompilation is never needed to re-point
    telemetry.  Works under ``vmap``/``lax.map`` (one delivery per
    batch element / iteration) and in donated-argument jits.
    """
    if not jit_stream_enabled():
        return
    import jax

    keys = tuple(values)

    def _deliver(*vals):
        sess = _ACTIVE
        if sess is not None:
            sess.emit("jit", name, **tags, **dict(zip(keys, vals)))

    jax.debug.callback(_deliver, *[values[k] for k in keys],
                       ordered=False)
