"""repro.obs — jit-safe structured telemetry (DESIGN.md §10).

Quickstart::

    from repro import obs

    with obs.session(jsonl="runs/trace.jsonl") as sess:
        results = run_grid_batched(...)      # instrumented layers emit
    # then: python -m repro.obs.report runs/trace.jsonl

Host-side API: :func:`record` (one event), :func:`counter`
(accumulating), :func:`scope` (phase wall-clock with a
``block_until_ready`` boundary), :func:`context` (tag everything in a
block), :func:`round_scope` (round tag + optional ``jax.profiler``
capture).  In-jit API: :func:`jit_tap` — streams values out of a
compiled step via ``jax.debug.callback``, gated at trace time so that
with no active session the compiled program is bit-identical to
uninstrumented code (the zero-overhead contract).

``retrace_probe`` wraps step functions before ``jax.jit`` and counts
compilations, flagging silent retrace storms.
"""
from .core import (ObsSession, active_session, context, counter, enabled,
                   jit_stream_enabled, jit_tap, record, session)
from .trace import (reset_retrace_counts, retrace_counts, retrace_probe,
                    round_scope, scope)

__all__ = [
    "ObsSession", "active_session", "context", "counter", "enabled",
    "jit_stream_enabled", "jit_tap", "record", "reset_retrace_counts",
    "retrace_counts", "retrace_probe", "round_scope", "scope",
    "session",
]
