"""Render an obs JSONL trace into human-readable run diagnostics.

    PYTHONPATH=src python -m repro.obs.report runs/trace.jsonl
    PYTHONPATH=src python -m repro.obs.report runs/trace.jsonl --csv rounds.csv

Sections (each present only when the trace carries its events):

* per-round table — phase wall-clock (train/solve/finish), payload-bit
  percentiles streamed from inside the jitted round step, user-rate
  percentiles + straggler latency + solver iteration counts from the
  phy solve, accuracy and latency-budget burn-down;
* phase-time breakdown — total seconds and share per phase name
  ("where did the round time go");
* wire traffic — bytes moved by the fused encode/decode kernels and
  the attained bandwidth over the train phase vs the roofline HBM
  bound ("is the wire path memory-bound yet");
* async rounds — the event-clock telemetry from the async round
  engine (``engine.async`` events): arrivals and staleness per round,
  effective participation, straggler gap, buffer occupancy and
  dropped-upload totals;
* recompilation summary — per-step trace counts from the retrace
  probes, flagging silent retrace storms;
* profiler captures — directories of ``jax.profiler`` traces armed via
  ``obs.session(profile_round=...)``.
"""
from __future__ import annotations

import argparse
import collections
import csv
import json
from typing import Any, Dict, List, Optional

try:                                    # repo-local roofline constants
    from repro.launch.roofline import HBM_BW
except Exception:                       # standalone use of the CLI
    HBM_BW = 819e9


def load_events(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def _mean(vals: List[float]) -> float:
    return sum(vals) / len(vals) if vals else float("nan")


def _num(v: Any) -> Optional[float]:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None
    return float(v)


# ------------------------------------------------------------- sections
def phase_breakdown(events: List[Dict]) -> List[Dict[str, Any]]:
    """Total / count / mean duration per phase name, largest first."""
    acc: Dict[str, List[float]] = collections.defaultdict(list)
    for e in events:
        if e.get("kind") == "phase":
            acc[e["name"]].append(float(e.get("dur_s", 0.0)))
    rows = [{"phase": name, "total_s": sum(d), "calls": len(d),
             "mean_s": _mean(d)} for name, d in acc.items()]
    rows.sort(key=lambda r: -r["total_s"])
    return rows


_ROUND_FIELDS = [
    # (column, kind, event name, field, reducer over the round's events)
    ("train_s", "phase", "train_round", "dur_s", sum),
    ("solve_s", "phase", "solve_uplink", "dur_s", sum),
    ("finish_s", "phase", "finish_round", "dur_s", sum),
    ("eval_s", "phase", "eval", "dur_s", sum),
    ("bits_min", "jit", "engine.jit_round", "bits_min", min),
    ("bits_med", "jit", "engine.jit_round", "bits_median", _mean),
    ("bits_p95", "jit", "engine.jit_round", "bits_p95", max),
    ("rate_min", "event", "phy.solve", "rate_min", min),
    ("rate_med", "event", "phy.solve", "rate_median", _mean),
    ("rate_p95", "event", "phy.solve", "rate_p95", max),
    ("straggler_s", "event", "phy.solve", "straggler_s_max", max),
    ("bisect_iters", "event", "phy.solve", "bisection_iters_mean",
     _mean),
    ("dink_iters", "event", "phy.solve", "dinkelbach_iters_mean",
     _mean),
    ("gap_s", "event", "engine.round", "straggler_gap_s", max),
    ("arrived", "event", "engine.async", "arrived", _mean),
    ("staleness", "event", "engine.async", "mean_staleness", _mean),
    ("eff_part", "event", "engine.async", "effective_participation",
     _mean),
    ("in_flight", "event", "engine.async", "in_flight", _mean),
    ("dropped", "event", "engine.async", "dropped_stale", sum),
    ("acc", "event", "engine.round", "acc", max),
    ("cum_lat_s", "event", "engine.round", "cum_latency_s", max),
    ("budget_left_s", "event", "engine.round", "budget_remaining_s",
     min),
]


def per_round_table(events: List[Dict]) -> List[Dict[str, Any]]:
    """One row per round tag, reducing over cells/replicates."""
    by_round: Dict[int, List[Dict]] = collections.defaultdict(list)
    for e in events:
        r = e.get("round")
        if isinstance(r, int):
            by_round[r].append(e)
    rows = []
    for t in sorted(by_round):
        row: Dict[str, Any] = {"round": t}
        for col, kind, name, field, reduce_ in _ROUND_FIELDS:
            vals = [_num(e.get(field)) for e in by_round[t]
                    if e.get("kind") == kind and e.get("name") == name]
            vals = [v for v in vals if v is not None]
            if vals:
                row[col] = reduce_(vals)
        rows.append(row)
    return rows


def wire_summary(events: List[Dict]) -> Dict[str, float]:
    """Aggregate fused encode/decode traffic and the attained train-
    phase bandwidth vs the roofline HBM bound."""
    enc_in = enc_out = dec_in = dec_out = 0.0
    calls = 0
    for e in events:
        if e.get("kind") != "jit":
            continue
        if e.get("name") == "wire.encode":
            enc_in += float(e.get("bytes_in", 0))
            enc_out += float(e.get("bytes_out", 0))
            calls += 1
        elif e.get("name") == "wire.decode":
            dec_in += float(e.get("bytes_in", 0))
            dec_out += float(e.get("bytes_out", 0))
            calls += 1
    if not calls:
        return {}
    total = enc_in + enc_out + dec_in + dec_out
    train_s = sum(float(e.get("dur_s", 0.0)) for e in events
                  if e.get("kind") == "phase"
                  and e.get("name") == "train_round")
    out = {"encode_bytes_in": enc_in, "encode_bytes_out": enc_out,
           "decode_bytes_in": dec_in, "decode_bytes_out": dec_out,
           "wire_calls": float(calls), "total_bytes": total,
           "compression_ratio": enc_in / enc_out if enc_out else 0.0}
    if train_s > 0:
        out["attained_gbps"] = total / train_s / 1e9
        out["roofline_fraction"] = (total / train_s) / HBM_BW
    return out


def async_summary(events: List[Dict]) -> Dict[str, float]:
    """Aggregate the async round engine's event-clock telemetry
    (``engine.async`` events): arrival/staleness distribution,
    effective participation, buffer occupancy and dropped-upload
    totals.  Empty for lockstep traces."""
    evs = [e for e in events
           if e.get("kind") == "event" and e.get("name") == "engine.async"]
    if not evs:
        return {}
    def col(field):
        return [v for v in (_num(e.get(field)) for e in evs)
                if v is not None]
    out = {
        "async_rounds": float(len(evs)),
        "mean_arrivals_per_round": _mean(col("arrived")),
        "mean_staleness": _mean(col("mean_staleness")),
        "max_staleness_observed": max(col("max_staleness") or [0.0]),
        "mean_effective_participation":
            _mean(col("effective_participation")),
        "mean_straggler_gap_s": _mean(col("straggler_gap_s")),
        "mean_in_flight": _mean(col("in_flight")),
        "dropped_stale_total": sum(col("dropped_stale")),
        "dropped_churn_total": sum(col("dropped_churn")),
    }
    return out


def resilience_summary(events: List[Dict]) -> Dict[str, float]:
    """Aggregate the fault-handling telemetry (DESIGN.md §14):
    quarantine totals, solver fallback stages, checkpoint/resume and
    IO-retry counts.  Empty when no detect/recover action fired."""
    def named(name):
        return [e for e in events if e.get("kind") == "event"
                and e.get("name") == name]

    out: Dict[str, float] = {}
    quar = named("resilience.quarantine")
    if quar:
        out["quarantined_users_total"] = sum(
            _num(e.get("quarantined_users")) or 0.0 for e in quar)
        out["rounds_with_quarantine"] = float(sum(
            1 for e in quar if (_num(e.get("quarantined_users")) or 0.0) > 0))
    fb = named("resilience.fallback")
    if fb:
        out["fallback_rounds"] = float(len(fb))
        out["fallback_cells_total"] = sum(
            _num(e.get("cells")) or 0.0 for e in fb)
        out["channel_rebuilds"] = float(sum(
            1 for e in fb if e.get("rebuilt")))
    ck = named("resilience.checkpoint")
    if ck:
        out["checkpoints_saved"] = float(len(ck))
    rs = named("resilience.resume")
    if rs:
        out["resumes"] = float(len(rs))
        out["last_resume_round"] = _num(rs[-1].get("round")) or 0.0
    io = named("resilience.io_retry")
    if io:
        out["io_retries"] = float(len(io))
    return out


def retrace_summary(events: List[Dict]) -> List[Dict[str, Any]]:
    final: Dict[str, Dict[str, Any]] = {}
    for e in events:
        if e.get("kind") == "retrace":
            final[e["name"]] = {"name": e["name"],
                                "count": int(e.get("count", 0)),
                                "storm": bool(e.get("storm", False))}
    rows = sorted(final.values(), key=lambda r: -r["count"])
    return rows


def profile_captures(events: List[Dict]) -> List[str]:
    return sorted({e.get("dir", "") for e in events
                   if e.get("name") == "profile.captured"})


# ------------------------------------------------------------ rendering
def _fmt(v: Any) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        a = abs(v)
        if a != 0 and (a >= 1e5 or a < 1e-3):
            return f"{v:.3g}"
        return f"{v:.4f}".rstrip("0").rstrip(".") or "0"
    return str(v)


def _table(rows: List[Dict[str, Any]], columns: List[str]) -> str:
    cells = [[_fmt(r.get(c)) for c in columns] for r in rows]
    widths = [max(len(c), *(len(row[i]) for row in cells)) if cells
              else len(c) for i, c in enumerate(columns)]
    lines = ["  ".join(c.rjust(w) for c, w in zip(columns, widths))]
    for row in cells:
        lines.append("  ".join(c.rjust(w) for c, w in zip(row, widths)))
    return "\n".join(lines)


def render_report(events: List[Dict],
                  csv_out: Optional[str] = None) -> str:
    parts: List[str] = []
    rounds = per_round_table(events)
    if rounds:
        cols = ["round"] + [c for c, *_ in _ROUND_FIELDS
                            if any(c in r for r in rounds)]
        parts.append("== per-round ==\n" + _table(rounds, cols))
        if csv_out:
            with open(csv_out, "w", newline="") as f:
                w = csv.DictWriter(f, fieldnames=cols,
                                   extrasaction="ignore")
                w.writeheader()
                w.writerows(rounds)
    phases = phase_breakdown(events)
    if phases:
        total = sum(r["total_s"] for r in phases) or 1.0
        for r in phases:
            r["share"] = f"{100.0 * r['total_s'] / total:.1f}%"
        parts.append("== phase time ==\n" + _table(
            phases, ["phase", "total_s", "calls", "mean_s", "share"]))
    wire = wire_summary(events)
    if wire:
        lines = [f"  {k}: {_fmt(v)}" for k, v in wire.items()]
        parts.append("== fused wire traffic ==\n" + "\n".join(lines))
    async_ = async_summary(events)
    if async_:
        lines = [f"  {k}: {_fmt(v)}" for k, v in async_.items()]
        parts.append("== async rounds ==\n" + "\n".join(lines))
    resil = resilience_summary(events)
    if resil:
        lines = [f"  {k}: {_fmt(v)}" for k, v in resil.items()]
        parts.append("== resilience ==\n" + "\n".join(lines))
    retraces = retrace_summary(events)
    if retraces:
        lines = [f"  {r['name']}: {r['count']} trace(s)"
                 + ("  ** RETRACE STORM **" if r["storm"] else "")
                 for r in retraces]
        parts.append("== recompilations ==\n" + "\n".join(lines))
    for d in profile_captures(events):
        parts.append(f"profiler trace captured under: {d}")
    if not parts:
        parts.append("(no obs events)")
    return "\n\n".join(parts)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="render an obs JSONL trace (see repro.obs)")
    ap.add_argument("trace", help="JSONL file written by obs.session")
    ap.add_argument("--csv", default=None, metavar="OUT",
                    help="also write the per-round table as CSV")
    args = ap.parse_args()
    print(render_report(load_events(args.trace), csv_out=args.csv))


if __name__ == "__main__":
    main()
