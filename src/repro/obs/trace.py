"""Phase-level wall-clock tracing + jit recompilation detector.

``scope("train_round")`` times a phase of the round lifecycle with an
explicit ``jax.block_until_ready`` boundary (register the phase's
device outputs with ``sc.block(...)``) so the recorded duration is real
compute, not dispatch time.  ``round_scope(t)`` tags everything inside
with the round number and arms the optional ``jax.profiler`` capture
when ``t == session.profile_round``.

``retrace_probe(name)`` wraps a python callable that is about to be
``jax.jit``-ed: the wrapper body only runs when jax TRACES the function
(a jit cache miss), so each execution of the wrapper is exactly one
(re)compilation.  Counts are kept globally (``retrace_counts()``) and
per session; a session flags a step that retraces ``retrace_storm``
times as a silent retrace storm.  The probe adds zero device work and
zero per-dispatch host work — cache hits never enter the wrapper.
"""
from __future__ import annotations

import collections
import contextlib
import functools
import os
import time
import warnings
from typing import Callable, Dict, Optional

from . import core


# ------------------------------------------------------------ phase scope
class _Scope:
    """Handle yielded by :func:`scope`; collects device values to block
    on at phase exit so the timing closes over finished compute."""

    def __init__(self) -> None:
        self._block: list = []

    def block(self, *values):
        """Register device values (arrays / pytrees) to
        ``block_until_ready`` at scope exit.  Returns the single value
        (or the tuple) for inline use."""
        self._block.extend(values)
        return values[0] if len(values) == 1 else values


@contextlib.contextmanager
def scope(name: str, **tags):
    """Time a phase; emits one ``kind: phase`` event with ``dur_s``.

    Without an active session: zero work — yields an inert handle and
    never touches the clock or the device.
    """
    sc = _Scope()
    sess = core.active_session()
    if sess is None:
        yield sc
        return
    t0 = time.perf_counter()
    try:
        yield sc
    finally:
        if sc._block:
            import jax
            jax.block_until_ready(sc._block)
        sess.emit("phase", name, dur_s=time.perf_counter() - t0, **tags)


@contextlib.contextmanager
def round_scope(t: int, **tags):
    """Tag the block's events with ``round=t``; start/stop the
    session's ``jax.profiler`` trace capture when ``t`` is the armed
    ``profile_round``."""
    sess = core.active_session()
    if sess is None:
        yield
        return
    profile = (sess.profile_round is not None and t == sess.profile_round
               and not sess.profiling)
    if profile:
        import jax
        os.makedirs(sess.profile_dir, exist_ok=True)
        jax.profiler.start_trace(sess.profile_dir)
        sess.profiling = True
    with core.context(round=t, **tags):
        try:
            yield
        finally:
            if profile:
                import jax
                jax.profiler.stop_trace()
                sess.profiling = False
                sess.emit("event", "profile.captured",
                          dir=sess.profile_dir)


# ----------------------------------------------------- recompile detector
_RETRACE_COUNTS: Dict[str, int] = collections.Counter()


def retrace_probe(name: str, fn: Optional[Callable] = None):
    """Decorator counting (re)traces of a to-be-jitted callable.

    Use as ``jax.jit(retrace_probe("sim.fused_step")(step))`` or as a
    decorator between ``@jax.jit`` and the ``def``.  The wrapper body
    executes only when jax traces the function, i.e. once per jit
    cache entry — each execution is one compilation of ``name``.
    """

    def deco(f: Callable) -> Callable:
        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            _RETRACE_COUNTS[name] += 1
            sess = core.active_session()
            if sess is not None:
                n = sess.retraces[name] = sess.retraces.get(name, 0) + 1
                storm = n >= sess.retrace_storm
                sess.emit("retrace", name, count=n, storm=storm)
                if n == sess.retrace_storm:
                    warnings.warn(
                        f"obs: {name!r} traced {n} times this session "
                        "— possible silent retrace storm (changing "
                        "shapes/dtypes or python-object hashing on a "
                        "hot step function)", stacklevel=2)
            return f(*args, **kwargs)
        return wrapper

    return deco(fn) if fn is not None else deco


def retrace_counts() -> Dict[str, int]:
    """Global (process-lifetime) trace counts per probed name."""
    return dict(_RETRACE_COUNTS)


def reset_retrace_counts() -> None:
    _RETRACE_COUNTS.clear()
