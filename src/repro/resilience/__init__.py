"""repro.resilience — fault injection, detection and recovery.

The failure model and recovery contract live in DESIGN.md §14; the
package splits along those three verbs:

* :mod:`faults`     — :class:`FaultPlan` (seeded per-round injection
  spec) and :class:`ResilienceConfig` (plan + recovery policy); what
  ``EngineConfig.resilience`` / ``run_grid_batched(resilience=...)``
  accept;
* :mod:`guards`     — the jit-safe inject/detect/quarantine primitives
  traced into the engine's fused round step (where-gated so the
  no-fault path stays bit-for-bit);
* :mod:`fallback`   — the host-side bounded power-solver fallback
  chain (retry-with-perturbed-init → Dinkelbach → max-sum →
  full-power uniform) promoted from the solvers' convergence
  diagnostics;
* :mod:`sweep_state` — cell-granular sweep checkpoint/resume on
  ``repro.checkpoint`` with IO retry/backoff (imported lazily: it
  reaches into ``repro.sim``, which itself imports the guards).
"""
from .fallback import (converged_rows, resilient_batched_solve,
                       uniform_power_solution)
from .faults import FaultPlan, ResilienceConfig
from .guards import (finite_rows, head_finite, inject_bitflips,
                     inject_delta_faults, payload_ok,
                     quarantine_weights, quarantined_count,
                     sanitize_head, sanitize_rows, update_ok,
                     zero_fault_arrays)

__all__ = [
    "FaultPlan", "ResilienceConfig", "SweepCheckpointer",
    "converged_rows", "finite_rows", "head_finite", "inject_bitflips",
    "inject_delta_faults", "payload_ok", "quarantine_weights",
    "quarantined_count", "resilient_batched_solve", "sanitize_head",
    "sanitize_rows", "uniform_power_solution", "update_ok",
    "zero_fault_arrays",
]


def __getattr__(name):
    # lazy: sweep_state imports repro.sim/checkpoint machinery, which
    # imports the guards above — a top-level import would cycle
    if name == "SweepCheckpointer":
        from .sweep_state import SweepCheckpointer
        return SweepCheckpointer
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
