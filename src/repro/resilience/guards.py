"""jit-safe inject / detect / quarantine primitives for the engine step.

Everything here is traced INTO the engine's jitted round step: faults
arrive as plain arrays (host-drawn, see faults.py), detection is pure
masking, and recovery is ``where``-gated so the no-fault path stays
bit-for-bit identical to an engine without a resilience layer —
``where(all-True, x, y)`` returns x's exact bits and ``word ^ 0`` is
the identity, so XLA computes the same values (the parity battery in
tests/test_resilience.py pins this on every aggregation path).

The quarantine contract (DESIGN.md §14): a payload is BAD when its
delta has a non-finite entry, its upload dropped mid-transfer, or its
wire checksum fails.  Bad payloads are (1) zeroed BEFORE encode — a
NaN row would otherwise poison the packed header and survive
weight-zeroing because ``NaN * 0 = NaN`` — and (2) masked out of the
weighted aggregation with the surviving users' ``rho`` renormalized to
sum to the original total.  A final finite guard on the aggregated
update freezes the global model for the round if everything failed.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.kernels.mixed_res import H_INF
from repro.kernels.ops import MixedResWire, verify_wire


def zero_fault_arrays(K: int) -> Dict[str, np.ndarray]:
    """The no-op fault draw (used when a step needs the arrays but the
    plan injects nothing this round)."""
    return {"nan": np.zeros(K, bool), "inf": np.zeros(K, bool),
            "drop": np.zeros(K, bool),
            "flip_mask": np.zeros(K, np.uint32),
            "flip_word": np.zeros(K, np.int32)}


def inject_delta_faults(flat: jnp.ndarray, faults: Dict) -> jnp.ndarray:
    """Poison selected users' [U, d] deltas with NaN / +inf."""
    flat = jnp.where(faults["nan"][:, None], jnp.float32(jnp.nan), flat)
    flat = jnp.where(faults["inf"][:, None], jnp.float32(jnp.inf), flat)
    return flat


def finite_rows(flat: jnp.ndarray) -> jnp.ndarray:
    """[U] bool — True where the user's whole delta is finite."""
    return jnp.all(jnp.isfinite(flat), axis=1)


def sanitize_rows(flat: jnp.ndarray, good: jnp.ndarray) -> jnp.ndarray:
    """Zero quarantined rows so non-finite payloads cannot reach the
    encoder (NaN survives multiplication by a zero weight)."""
    return jnp.where(good[:, None], flat, 0.0)


def inject_bitflips(wire: MixedResWire, faults: Dict) -> MixedResWire:
    """Flip one sign-plane bit per selected user (post-encode, i.e. in
    transit AFTER the checksum was stamped — that is what the decode
    verify is for).  flip_mask == 0 users xor with 0: bit-identical."""
    signs = wire.signs
    U = signs.shape[0]
    flat_s = signs.reshape(U, -1)
    idx = faults["flip_word"] % flat_s.shape[1]
    rows = jnp.arange(U)
    flat_s = flat_s.at[rows, idx].set(
        flat_s[rows, idx] ^ faults["flip_mask"])
    return wire._replace(signs=flat_s.reshape(signs.shape))


def head_finite(wire: MixedResWire) -> jnp.ndarray:
    """[U] bool — True where the user's delta was entirely finite,
    read off the encoded header instead of an O(U d) isfinite pass:
    ``H_INF`` is the row's inf-norm through a NaN-propagating max, so
    it is non-finite iff SOME element was (an all-finite row cannot
    overflow f32's max into inf through abs/max)."""
    return jnp.isfinite(wire.head[:, H_INF])


def sanitize_head(wire: MixedResWire, good: jnp.ndarray) -> MixedResWire:
    """Zero quarantined rows' header lanes so their (garbage) planes
    decode to exactly 0 — every decode scale (dw_q, step, dbar) lives
    in the head, and a zeroed head is bit-for-bit what encoding a
    zeroed row produces.  O(U) instead of zeroing [U, d] deltas before
    the encoder; ``where(all-True, ...)`` keeps the no-fault head
    untouched."""
    return wire._replace(head=jnp.where(good[:, None], wire.head, 0.0))


def payload_ok(good_pre: jnp.ndarray, wire: MixedResWire,
               checksum: bool) -> jnp.ndarray:
    """[U] bool — pre-encode verdict folded with the wire checksum."""
    if not checksum:
        return good_pre
    return good_pre & verify_wire(wire)


def quarantine_weights(weights: jnp.ndarray, ok: jnp.ndarray):
    """Mask bad users out of the aggregation, renormalizing the
    survivors' weights to the original total.  Returns ``(w', ok)``
    where ``w'`` is bitwise ``weights`` when every user is ok."""
    okf = ok.astype(weights.dtype)
    wsum = jnp.sum(weights)
    wsum_good = jnp.sum(weights * okf)
    scale = wsum / jnp.where(wsum_good > 0, wsum_good, 1.0)
    any_bad = ~jnp.all(ok)
    return jnp.where(any_bad, weights * okf * scale, weights), ok


def quarantined_count(ok: jnp.ndarray, active: jnp.ndarray
                      ) -> jnp.ndarray:
    """Scalar int32 — quarantined ACTIVE users (padded cohort slots and
    churned-out users never count)."""
    return jnp.sum(jnp.where(ok, 0, 1) * (active > 0).astype(jnp.int32))


def update_ok(agg: jnp.ndarray) -> jnp.ndarray:
    """Scalar bool — final finite guard on the aggregated update."""
    return jnp.all(jnp.isfinite(agg))


__all__ = ["finite_rows", "head_finite", "inject_bitflips",
           "inject_delta_faults", "payload_ok", "quarantine_weights",
           "quarantined_count", "sanitize_head", "sanitize_rows",
           "update_ok", "zero_fault_arrays"]
