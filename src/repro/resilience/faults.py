"""FaultPlan / ResilienceConfig — the failure model's specification.

A :class:`FaultPlan` names WHAT can go wrong and with what per-round
probability; everything is seeded and drawn host-side per round
(``numpy.random.default_rng((seed, stream, t))``), so a fault trace is
exactly reproducible and the jitted step only ever sees plain arrays —
the fault draw is an *input* of the step, never a branch inside it.
:class:`ResilienceConfig` bundles a plan with the recovery policy
(guards on/off, the solver fallback chain, IO retry/backoff) and is
what `EngineConfig.resilience` / `run_grid_batched(resilience=...)`
accept.

The axes (DESIGN.md §14):

* ``nan_delta_prob`` / ``inf_delta_prob`` — a user's local delta turns
  non-finite before quantization (diverged optimizer, bad batch);
* ``bitflip_prob``  — one bit of the user's packed sign plane flips in
  transit (detected only when ``WirePath(checksum=True)``);
* ``dropout_prob``  — the upload is lost mid-transfer: the payload is
  treated as never received;
* ``channel_corrupt_prob`` — the cached channel-estimate bundle decays
  (NaN coefficients), recovered by rebuilding from realizations;
* ``solver_fail_rounds`` — the primary power solve is declared
  non-converged on these rounds, exercising the fallback chain;
* ``kill_after_rounds`` — sweep preemption: the process SIGKILLs
  itself after this many completed+checkpointed rounds (the
  kill-and-resume chaos test).

``FaultPlan.none()`` draws all-zero masks: every guard reduces to
``where(False, ...)`` / xor-with-0 identities, which is how the
bit-for-bit no-fault parity contract is kept.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

# independent named substreams off the plan seed
_DELTA_STREAM = 0xFA17    # per-user delta/plane/dropout draws
_CHAN_STREAM = 0xC047     # channel-estimate corruption
_RETRY_STREAM = 0x5EED    # perturbed solver restarts


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded per-round fault injection spec (probabilities per round,
    per user where a user axis exists)."""
    nan_delta_prob: float = 0.0
    inf_delta_prob: float = 0.0
    bitflip_prob: float = 0.0
    dropout_prob: float = 0.0
    channel_corrupt_prob: float = 0.0
    solver_fail_rounds: Tuple[int, ...] = ()
    kill_after_rounds: Optional[int] = None
    seed: int = 0

    @classmethod
    def none(cls) -> "FaultPlan":
        """The identity plan — injects nothing, ever."""
        return cls()

    @property
    def is_none(self) -> bool:
        return (self.nan_delta_prob == 0 and self.inf_delta_prob == 0
                and self.bitflip_prob == 0 and self.dropout_prob == 0
                and self.channel_corrupt_prob == 0
                and not self.solver_fail_rounds
                and self.kill_after_rounds is None)

    # ------------------------------------------------------ host draws
    def draw(self, t: int, K: int, replicate: Optional[int] = None
             ) -> Dict[str, np.ndarray]:
        """Per-round fault masks for K users (numpy, host-side).

        Keys: ``nan``/``inf``/``drop`` [K] bool, ``flip_mask`` [K]
        uint32 (0 = no flip; else a single-bit xor mask) and
        ``flip_word`` [K] int32 (word index into the flattened sign
        plane, reduced mod the word count device-side)."""
        key = ((self.seed, _DELTA_STREAM, t) if replicate is None
               else (self.seed, _DELTA_STREAM, t, replicate))
        rng = np.random.default_rng(key)
        nan = rng.random(K) < self.nan_delta_prob
        inf = rng.random(K) < self.inf_delta_prob
        flip = rng.random(K) < self.bitflip_prob
        drop = rng.random(K) < self.dropout_prob
        bit = rng.integers(0, 32, K).astype(np.uint32)
        word = rng.integers(0, np.int32(2 ** 31 - 1), K).astype(np.int32)
        flip_mask = np.where(flip, np.uint32(1) << bit,
                             np.uint32(0)).astype(np.uint32)
        return {"nan": nan, "inf": inf, "drop": drop,
                "flip_mask": flip_mask, "flip_word": word}

    def solver_forced_failure(self, t: int) -> bool:
        """True when round t's primary power solve must be treated as
        non-converged regardless of its flags."""
        return t in self.solver_fail_rounds

    def channel_corrupt(self, t: int) -> bool:
        rng = np.random.default_rng((self.seed, _CHAN_STREAM, t))
        return bool(rng.random() < self.channel_corrupt_prob)

    def retry_jitter(self, t: int, shape) -> np.ndarray:
        """Perturbation for retry-with-perturbed-init restarts."""
        rng = np.random.default_rng((self.seed, _RETRY_STREAM, t))
        return rng.uniform(-0.05, 0.05, shape)


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """A fault plan plus the recovery policy that answers it.

    ``solver_chain`` names the bounded fallback order tried after the
    primary controller (and its one perturbed-init retry) fails;
    ``"uniform"`` is the terminal full-power stage and always accepted.
    ``guards=False`` keeps injection without detection (for measuring
    blast radius in chaos tests)."""
    faults: FaultPlan = FaultPlan.none()
    guards: bool = True
    solver_chain: Tuple[str, ...] = ("dinkelbach", "max-sum-rate",
                                     "uniform")
    solver_retries: int = 1
    io_retries: int = 3
    io_backoff_s: float = 0.05

    @classmethod
    def none(cls) -> "ResilienceConfig":
        """Guards on, nothing injected — the production posture."""
        return cls()


__all__ = ["FaultPlan", "ResilienceConfig"]
