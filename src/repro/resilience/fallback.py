"""Bounded power-solver fallback chain (host-side, per sweep round).

PR 6's batched solvers already *return* convergence diagnostics
(``bisection_converged``, ``dinkelbach_converged``/``residual``/
``safeguard``, ``maxsum_grad_norm``) — the drivers just never looked.
This module promotes them to control flow: after the primary solve,
each cell row is judged converged-and-finite; failed rows get ONE
bounded retry (perturbed restarts for max-sum, doubled iteration
budgets for the deterministic solvers — re-running those unchanged
would reproduce the same failure), then walk the configured chain
(Dinkelbach → max-sum → full-power uniform by default).  The uniform
stage is terminal: full power for every active user always yields
finite rates, so a round can degrade but never crash.

A non-finite solution row additionally triggers the channel-recovery
hook: the caller passes ``rebuild()`` (re-derive the ChannelBatch from
the retained realizations) and the chain re-solves on the rebuilt
bundle — the recovery path for corrupted channel estimates.

Everything merges row-wise in numpy (the drivers are host loops), and
every recovery emits a ``resilience.fallback`` obs event.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.phy import solvers as _solvers
from repro.phy.solvers import BatchedPowerSolution

from .faults import ResilienceConfig


def converged_rows(sol: BatchedPowerSolution, mask: np.ndarray
                   ) -> np.ndarray:
    """[B] bool — per-cell verdict from the solver's own diagnostics
    plus finiteness of the power/latency rows (active users only)."""
    m = np.asarray(mask) > 0
    p = np.asarray(sol.p)
    lat = np.asarray(sol.latencies)
    ok = np.all(np.where(m, np.isfinite(p), True), axis=1)
    ok &= np.all(np.where(m, np.isfinite(lat), True), axis=1)
    info = sol.info
    for key in ("bisection_converged", "dinkelbach_converged"):
        if key in info:
            ok &= np.asarray(info[key]).astype(bool)
    if "maxsum_grad_norm" in info:
        ok &= np.isfinite(np.asarray(info["maxsum_grad_norm"]))
    return ok


def finite_rows(sol: BatchedPowerSolution, mask: np.ndarray
                ) -> np.ndarray:
    """[B] bool — finiteness only (the channel-corruption symptom)."""
    m = np.asarray(mask) > 0
    p = np.asarray(sol.p)
    lat = np.asarray(sol.latencies)
    ok = np.all(np.where(m, np.isfinite(p), True), axis=1)
    return ok & np.all(np.where(m, np.isfinite(lat), True), axis=1)


def uniform_power_solution(cb, bits, mask) -> BatchedPowerSolution:
    """The terminal fallback: full power for every active user."""
    bits = jnp.asarray(bits, jnp.float32)
    maskj = jnp.asarray(mask, jnp.float32)
    return _solvers._finish(cb, bits, maskj, maskj, {})


def _retry_solve(power, cb, bits, mask, plan, t
                 ) -> Optional[BatchedPowerSolution]:
    """One bounded retry of the primary controller: perturbed-init
    restarts for max-sum; doubled iteration budget for the
    deterministic solvers (an unchanged re-run would reproduce the
    failure bit-for-bit)."""
    name = power.name
    if name == "max-sum-rate":
        mask_np = np.asarray(mask, np.float64)
        starts = _solvers.maxsum_starts(mask_np, power.restarts)
        jitter = plan.retry_jitter(t, starts.shape) if plan is not None \
            else np.zeros(starts.shape)
        starts = np.clip(starts + jitter * (starts > 0), 0.0, 1.0)
        return _solvers.maxsum_solve(cb, bits, mask=mask,
                                     iters=power.iters, lr=power.lr,
                                     starts=starts)
    if name == "bisection-lp":
        return _solvers.bisection_solve(cb, bits, mask=mask,
                                        eps_rel=power.eps_rel,
                                        max_iters=2 * power.max_iters)
    if name == "dinkelbach":
        return _solvers.dinkelbach_solve(
            cb, bits, mask=mask, p_circuit_w=power.p_circuit_w,
            outer=2 * power.outer, inner=power.inner, lr=power.lr,
            tol=power.tol)
    return None


def _chain_solve(stage: str, cb, bits, mask) -> BatchedPowerSolution:
    if stage == "dinkelbach":
        return _solvers.dinkelbach_solve(cb, bits, mask=mask)
    if stage == "max-sum-rate":
        return _solvers.maxsum_solve(cb, bits, mask=mask)
    if stage == "bisection-lp":
        return _solvers.bisection_solve(cb, bits, mask=mask)
    if stage == "uniform":
        return uniform_power_solution(cb, bits, mask)
    raise KeyError(f"unknown fallback stage {stage!r}")


def _merge(base: BatchedPowerSolution, alt: BatchedPowerSolution,
           take: np.ndarray) -> BatchedPowerSolution:
    """Row-wise merge: rows where ``take`` adopt ``alt``'s solution."""
    sel = take[:, None]
    return BatchedPowerSolution(
        p=np.where(sel, np.asarray(alt.p), np.asarray(base.p)),
        rates=np.where(sel, np.asarray(alt.rates),
                       np.asarray(base.rates)),
        latencies=np.where(sel, np.asarray(alt.latencies),
                           np.asarray(base.latencies)),
        info=base.info)


def resilient_batched_solve(
        power, cb, bits, mask, *, config: ResilienceConfig,
        t: int = 0, rebuild: Optional[Callable] = None,
        obs_tag: str = "") -> Tuple[BatchedPowerSolution, np.ndarray,
                                    Optional[object]]:
    """Primary solve → retry → fallback chain, per cell row.

    Returns ``(solution, fallbacks [B] int32, rebuilt_cb)`` where
    ``fallbacks`` counts the recovery stages each row consumed (0 =
    primary converged first try — the common case, in which the primary
    solution object is returned UNTOUCHED, keeping the no-fault path's
    arrays identical to a driver without this wrapper) and
    ``rebuilt_cb`` is the recovered ChannelBatch when the corruption
    hook fired (the caller refreshes its cache with it)."""
    plan = config.faults
    solve = _solvers.batched_solver(power)
    bits_j = jnp.asarray(bits)
    mask_j = jnp.asarray(mask)
    sol = solve(cb, bits_j, mask=mask_j)
    forced = plan.solver_forced_failure(t)
    ok = converged_rows(sol, mask) & (not forced)
    B = ok.shape[0]
    fallbacks = np.zeros(B, np.int32)
    if ok.all():
        return sol, fallbacks, None

    rebuilt_cb = None
    stages_run = []
    # channel recovery: non-finite rows mean the bundle itself decayed
    if rebuild is not None and not finite_rows(sol, mask).all():
        rebuilt_cb = rebuild()
        cb = rebuilt_cb
        alt = solve(cb, bits_j, mask=mask_j)
        take = ~ok
        sol = _merge(sol, alt, take)
        fallbacks += take.astype(np.int32)
        ok = ok | (take & converged_rows(alt, mask) & (not forced))
        stages_run.append("channel_rebuild")
    if not ok.all() and config.solver_retries > 0:
        alt = _retry_solve(power, cb, bits_j, mask_j, plan, t)
        if alt is not None:
            take = ~ok & converged_rows(alt, mask) & (not forced)
            if take.any():
                sol = _merge(sol, alt, take)
                fallbacks[take] += 1
                ok |= take
            stages_run.append(f"retry:{power.name}")
    for stage in config.solver_chain:
        if ok.all():
            break
        if stage == power.name:
            continue
        alt = _chain_solve(stage, cb, bits_j, mask_j)
        accepted = converged_rows(alt, mask) if stage != "uniform" \
            else np.ones(B, bool)
        take = ~ok & accepted
        if take.any():
            sol = _merge(sol, alt, take)
            fallbacks[take] += 1
            ok |= take
            stages_run.append(stage)
    if _obs.enabled() and fallbacks.any():
        _obs.record("resilience.fallback", t=t, power=power.name,
                    tag=obs_tag, cells=int((fallbacks > 0).sum()),
                    stages=",".join(stages_run), forced=bool(forced),
                    rebuilt=rebuilt_cb is not None)
    return sol, fallbacks, rebuilt_cb


__all__ = ["converged_rows", "finite_rows", "resilient_batched_solve",
           "uniform_power_solution"]
