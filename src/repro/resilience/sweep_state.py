"""Cell-granular sweep checkpoint/resume on ``repro.checkpoint``.

:class:`SweepCheckpointer` gives ``run_grid_batched`` a durable round
frontier: after every ``every``-th lockstep round of a scenario, the
full training state of EVERY track and the accounting of EVERY
(quantizer, power) cell is written atomically (one ``save_checkpoint``
.npz for the device pytrees + its JSON metadata for the host state),
and a completed scenario's result rows land in ``rows.json``.  A
process killed mid-sweep (``kill -9`` included — the chaos suite does
exactly that) re-runs the same ``run_grid_batched`` call and continues
from the last completed (scenario, quantizer, power, round) frontier:
finished scenarios are skipped outright from ``rows.json``, the
in-flight scenario restores its newest valid checkpoint and resumes at
round ``t0 + 1``.

What is (and is not) serialized:

* device state — per-track params/quantizer-state pytrees (replicated:
  the stacked [R] carries plus each cell's per-replicate final-params
  snapshots) and the async clock's payload buffer go in the .npz;
* host state — numpy Generator ``bit_generator.state`` dicts, per-cell
  RoundLog lists, latency/alive/max_p accounting and the async clock's
  host arrays go in the JSON metadata (all fixed-shape device trees in
  the archive, all variable-length state in JSON);
* channels are NOT serialized: realizations replay deterministically
  from the engine's redraw rule (``make_channel(cfg, channel_seed +
  t')`` at the last redraw round ``t' <= t0``), so restore rebuilds
  them instead of shipping [M, K] grids to disk.

Every IO call runs under bounded retry/backoff
(``ResilienceConfig.io_retries`` / ``io_backoff_s``); restore leans on
the hardened ``restore_checkpoint`` (truncated/corrupt archives fall
back to the newest valid retained step).  ``FaultPlan.
kill_after_rounds`` arms the preemption fault: the process SIGKILLs
itself after that many successful round saves.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import signal
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro import obs as _obs
from repro.checkpoint.io import (latest_step, restore_checkpoint,
                                 save_checkpoint)

from .faults import ResilienceConfig

_ROWS_FILE = "rows.json"
_KEEP = 2      # retained round checkpoints per scenario


def _with_retry(fn: Callable, retries: int, backoff_s: float,
                what: str = "sweep checkpoint IO"):
    """Run ``fn`` with bounded retry + exponential backoff on OSError —
    the transient-filesystem recovery path (DESIGN.md §14)."""
    last: Optional[BaseException] = None
    for attempt in range(max(0, retries) + 1):
        try:
            return fn()
        except OSError as e:        # noqa: PERF203 - bounded retry loop
            last = e
            if attempt < retries:
                if _obs.enabled():
                    _obs.record("resilience.io_retry", what=what,
                                attempt=attempt + 1, error=str(e))
                time.sleep(backoff_s * (2 ** attempt))
    raise last


def _slug(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]+", "_", name)


def _rng_state(rng: np.random.Generator) -> Dict:
    return rng.bit_generator.state


def _restore_rng(state: Dict) -> np.random.Generator:
    rng = np.random.default_rng(0)
    rng.bit_generator.state = state
    return rng


def _log_to_dict(log) -> Dict:
    d = dataclasses.asdict(log)
    d["bits_per_user"] = np.asarray(log.bits_per_user,
                                    np.float64).tolist()
    if d.get("test_acc") is not None:
        d["test_acc"] = float(d["test_acc"])
    return d


def _log_from_dict(d: Dict):
    from repro.fl.loop import RoundLog

    d = dict(d)
    d["bits_per_user"] = np.asarray(d["bits_per_user"], np.float64)
    known = {f.name for f in dataclasses.fields(RoundLog)}
    return RoundLog(**{k: v for k, v in d.items() if k in known})


def _device(tree):
    import jax.numpy as jnp

    return jax.tree_util.tree_map(lambda x: jnp.asarray(x), tree)


def _replay_channel(engine, chan, t0: int, replicate: Optional[int]):
    """The deterministic channel replay: re-derive the realization in
    force at round ``t0 + 1`` from the engine's redraw rule instead of
    serializing [M, K] grids."""
    from repro.sim.engine import make_channel

    every = engine.engine_cfg.redraw_channel_every
    if chan is None or every <= 0:
        return chan
    tp = 0
    for t in range(2, t0 + 1):
        if (t - 1) % every == 0:
            tp = t
    if tp == 0:
        return chan
    seed = (engine.engine_cfg.channel_seed + tp if replicate is None
            else engine._repl_chan_seed(replicate, tp))
    return make_channel(chan.cfg, seed=seed)


class SweepCheckpointer:
    """Round-granular checkpoint/resume for ``run_grid_batched``.

    One instance per driver call; ``directory`` is the durable root
    (``rows.json`` + one ``scn_<name>/`` checkpoint dir per scenario).
    """

    def __init__(self, directory: str,
                 resilience: Optional[ResilienceConfig] = None,
                 every: int = 1):
        if every < 1:
            raise ValueError(f"checkpoint_every must be >= 1, got {every}")
        self.directory = directory
        self.resilience = resilience or ResilienceConfig.none()
        self.every = every
        self._saves = 0
        os.makedirs(directory, exist_ok=True)
        self._rows: Dict[str, List[Dict]] = self._load_rows()

    # ------------------------------------------------- completed rows
    def _retry(self, fn, what):
        return _with_retry(fn, self.resilience.io_retries,
                           self.resilience.io_backoff_s, what)

    def _load_rows(self) -> Dict[str, List[Dict]]:
        path = os.path.join(self.directory, _ROWS_FILE)
        if not os.path.exists(path):
            return {}
        try:
            with open(path) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            warnings.warn(f"sweep rows file {path} unreadable ({e}); "
                          "restarting the sweep from scratch",
                          stacklevel=2)
            return {}

    def completed_rows(self, scenario_name: str,
                       expected_cells: int) -> Optional[List[Dict]]:
        """The scenario's finished result rows, or None when it must
        (re)run — a grid reshape invalidates the stored rows."""
        rows = self._rows.get(scenario_name)
        if rows is None or len(rows) != expected_cells:
            return None
        return rows

    def mark_scenario_done(self, scenario_name: str,
                           rows: List[Dict]) -> None:
        """Record the scenario's rows atomically (tmp + os.replace)."""
        self._rows[scenario_name] = rows
        path = os.path.join(self.directory, _ROWS_FILE)

        def write():
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(self._rows, f)
            os.replace(tmp, path)

        self._retry(write, "rows.json")
        if _obs.enabled():
            _obs.record("resilience.scenario_done",
                        scenario=scenario_name, cells=len(rows))

    def scenario_dir(self, scenario_name: str) -> str:
        return os.path.join(self.directory, f"scn_{_slug(scenario_name)}")

    # ------------------------------------------------------ save round
    def save_round(self, scn, tracks: List, t: int) -> None:
        """Persist every track + cell of the scenario after round t."""
        replicated = hasattr(tracks[0].state, "chans") if tracks else False
        tree: Dict[str, Any] = {}
        meta: Dict[str, Any] = {"replicated": replicated, "tracks": []}
        for i, tr in enumerate(tracks):
            node: Dict[str, Any] = {"params": tr.state.params,
                                    "qstate": tr.state.qstate}
            clock = tr.state.async_clock
            if clock is not None:
                node["clock_buffer"] = clock.buffer
            tm: Dict[str, Any] = {}
            if replicated:
                tm["rngs"] = [_rng_state(r) for r in tr.state.rngs]
                tm["part_rngs"] = [_rng_state(r)
                                   for r in tr.state.part_rngs]
                cells = []
                for j, cell in enumerate(tr.cells):
                    eng = tr.engine
                    snaps = [cell.params[r] if not cell.alive[r]
                             else eng.replicate_params(tr.state, r)
                             for r in range(tr.state.R)]
                    node[f"cell{j}_params"] = jax.tree_util.tree_map(
                        lambda *xs: np.stack([np.asarray(x)
                                              for x in xs]), *snaps)
                    cells.append({
                        "logs": [[_log_to_dict(l) for l in logs]
                                 for logs in cell.logs],
                        "cum_latency": cell.cum_latency.tolist(),
                        "alive": cell.alive.tolist(),
                        "rounds_done": cell.rounds_done.tolist(),
                        "max_p": float(cell.max_p)})
                tm["cells"] = cells
            else:
                tm["rng"] = _rng_state(tr.state.rng)
                tm["part_rng"] = _rng_state(tr.state.part_rng)
                tm["cum_latency"] = float(tr.state.cum_latency)
                tm["rounds_done"] = int(tr.state.rounds_done)
                tm["cells"] = [{
                    "logs": [_log_to_dict(l) for l in cell.acct.logs],
                    "cum_latency": float(cell.acct.cum_latency),
                    "rounds_done": int(cell.acct.rounds_done),
                    "alive": bool(cell.alive),
                    "max_p": float(cell.max_p)}
                    for cell in tr.cells]
            if clock is not None:
                tm["clock"] = {
                    "in_flight": clock.in_flight.tolist(),
                    "remaining_s": clock.remaining_s.tolist(),
                    "staleness": clock.staleness.tolist(),
                    "uploads_started": int(clock.uploads_started),
                    "arrived_total": int(clock.arrived_total),
                    "dropped_stale": int(clock.dropped_stale),
                    "dropped_churn": int(clock.dropped_churn)}
            tree[f"track{i}"] = node
            meta["tracks"].append(tm)

        directory = self.scenario_dir(scn.name)
        self._retry(
            lambda: save_checkpoint(directory, t, tree, metadata=meta,
                                    keep=_KEEP),
            f"scenario checkpoint {scn.name}@{t}")
        self._saves += 1
        if _obs.enabled():
            _obs.record("resilience.checkpoint", scenario=scn.name,
                        round=t, tracks=len(tracks))
        kill_after = self.resilience.faults.kill_after_rounds
        if kill_after is not None and self._saves >= kill_after:
            # sweep preemption fault: die the hard way, AFTER the save
            # landed — resume must pick up from this exact frontier
            os.kill(os.getpid(), signal.SIGKILL)

    # --------------------------------------------------------- restore
    def restore_round(self, scn, tracks: List) -> int:
        """Restore the scenario's newest valid checkpoint into freshly
        built tracks; returns the completed-round frontier t0 (0 when
        nothing valid is on disk — run from the start)."""
        directory = self.scenario_dir(scn.name)
        if latest_step(directory) is None:
            return 0
        replicated = hasattr(tracks[0].state, "chans") if tracks else False
        template: Dict[str, Any] = {}
        for i, tr in enumerate(tracks):
            node: Dict[str, Any] = {"params": tr.state.params,
                                    "qstate": tr.state.qstate}
            if tr.state.async_clock is not None:
                node["clock_buffer"] = tr.state.async_clock.buffer
            if replicated:
                for j in range(len(tr.cells)):
                    node[f"cell{j}_params"] = tr.state.params
            template[f"track{i}"] = node
        try:
            tree, t0, meta = self._retry(
                lambda: restore_checkpoint(directory, template),
                f"scenario restore {scn.name}")
        except Exception as e:      # no valid retained checkpoint
            warnings.warn(
                f"no restorable checkpoint for scenario {scn.name!r} "
                f"({e}); re-running from round 1", stacklevel=2)
            return 0
        if meta.get("replicated", False) != replicated or \
                len(meta.get("tracks", ())) != len(tracks):
            warnings.warn(
                f"checkpoint layout for {scn.name!r} does not match the "
                "current grid; re-running from round 1", stacklevel=2)
            return 0
        for i, tr in enumerate(tracks):
            node, tm = tree[f"track{i}"], meta["tracks"][i]
            tr.state.params = _device(node["params"])
            tr.state.qstate = _device(node["qstate"])
            clock = tr.state.async_clock
            if clock is not None and "clock" in tm:
                clock.buffer = _device(node["clock_buffer"])
                cm = tm["clock"]
                clock.in_flight = np.asarray(cm["in_flight"], bool)
                clock.remaining_s = np.asarray(cm["remaining_s"],
                                               np.float64)
                clock.staleness = np.asarray(cm["staleness"], np.int64)
                clock.uploads_started = int(cm["uploads_started"])
                clock.arrived_total = int(cm["arrived_total"])
                clock.dropped_stale = int(cm["dropped_stale"])
                clock.dropped_churn = int(cm["dropped_churn"])
                clock.payload = None
            if replicated:
                tr.state.rngs = [_restore_rng(s) for s in tm["rngs"]]
                tr.state.part_rngs = [_restore_rng(s)
                                      for s in tm["part_rngs"]]
                tr.state.rounds_done = t0
                for r in range(tr.state.R):
                    tr.state.chans[r] = _replay_channel(
                        tr.engine, tr.state.chans[r], t0, r)
                for j, cell in enumerate(tr.cells):
                    cm = tm["cells"][j]
                    cell.logs = [[_log_from_dict(d) for d in logs]
                                 for logs in cm["logs"]]
                    cell.cum_latency = np.asarray(cm["cum_latency"],
                                                  np.float64)
                    cell.alive = np.asarray(cm["alive"], bool)
                    cell.rounds_done = np.asarray(cm["rounds_done"],
                                                  np.int64)
                    cell.max_p = float(cm["max_p"])
                    snaps = _device(node[f"cell{j}_params"])
                    cell.params = [
                        None if cell.alive[r]
                        else jax.tree_util.tree_map(lambda x, _r=r:
                                                    x[_r], snaps)
                        for r in range(tr.state.R)]
            else:
                tr.state.rng = _restore_rng(tm["rng"])
                tr.state.part_rng = _restore_rng(tm["part_rng"])
                tr.state.cum_latency = float(tm["cum_latency"])
                tr.state.rounds_done = int(tm["rounds_done"])
                tr.state.chan = _replay_channel(tr.engine,
                                                tr.state.chan, t0, None)
                for j, cell in enumerate(tr.cells):
                    cm = tm["cells"][j]
                    cell.acct.logs = [_log_from_dict(d)
                                      for d in cm["logs"]]
                    cell.acct.cum_latency = float(cm["cum_latency"])
                    cell.acct.rounds_done = int(cm["rounds_done"])
                    cell.acct.params = tr.state.params
                    cell.alive = bool(cm["alive"])
                    cell.max_p = float(cm["max_p"])
        if _obs.enabled():
            _obs.record("resilience.resume", scenario=scn.name, round=t0)
        return int(t0)


__all__ = ["SweepCheckpointer", "_with_retry"]
