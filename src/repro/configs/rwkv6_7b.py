"""rwkv6-7b [ssm] — Finch, data-dependent decay linear attention
(attention-free).  [arXiv:2404.05892]

32L d_model=4096 d_ff=14336 vocab=65536, head_dim=64 (64 heads).
long_500k runs natively: the WKV state is O(1) per head.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    attn_type="none",
    block_pattern="R" * 32,
    ssm_state_dim=64,          # == head_dim for WKV
    ssm_head_dim=64,
    source="arXiv:2404.05892",
)
