"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8, qk_norm.
[hf:Qwen/Qwen3-30B-A3B family, 235B-A22B scale]

94L d_model=4096 64H (GQA kv=4, head_dim=128) moe_d_ff=1536 vocab=151936.
Largest assigned model (~235B total, ~22B active): uses fully-sharded
("fsdp") parameter placement; the paper's quantized delta aggregation
applies across the pod axis in the multi-pod mesh (see DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    d_ff=12288,                # dense fallback width (unused when MoE)
    vocab_size=151936,
    attn_type="gqa",
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    num_experts=128,
    num_shared_experts=0,
    top_k=8,
    moe_d_ff=1536,
    fsdp=True,
    source="hf:Qwen/Qwen3-30B-A3B",
)
