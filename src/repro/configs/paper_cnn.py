"""The paper's own CNN (§IV) — used by the FL simulation layer.

Conv2D(32, 3x3, ReLU) -> MaxPool(2x2) -> Flatten -> Dense(64, ReLU)
-> Dense(n_classes, softmax).  Input (32,32,3) for CIFAR-10/100 and
(28,28,3) for Fashion-MNIST (grayscale pre-processed to 3 channels).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperCNNConfig:
    name: str = "paper-cnn"
    input_hw: int = 32             # 32 for CIFAR, 28 for Fashion-MNIST
    channels: int = 3
    conv_filters: int = 32
    dense_units: int = 64
    n_classes: int = 10

    @property
    def flat_dim(self) -> int:
        h = self.input_hw - 2      # valid 3x3 conv
        h = h // 2                 # 2x2 maxpool
        return h * h * self.conv_filters


CIFAR10 = PaperCNNConfig(name="paper-cnn-cifar10", input_hw=32, n_classes=10)
CIFAR100 = PaperCNNConfig(name="paper-cnn-cifar100", input_hw=32,
                          n_classes=100)
FASHION = PaperCNNConfig(name="paper-cnn-fashion", input_hw=28, n_classes=10)
