"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B]

24L d_model=2048 16H (kv=16, head_dim=128) moe_d_ff=1408 vocab=151936.
60 routed experts padded to 64 for clean expert-parallel sharding over
the 16-way model axis (router logits of padding experts are masked).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    d_ff=5632,                 # shared-expert path width (4 x 1408)
    vocab_size=151936,
    attn_type="gqa",
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    num_experts=60,
    num_experts_padded=64,
    num_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
