"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]

81L d_model=3584 d_ff=14336 vocab=32000 ssm_state=64.  Every 6th block
is a SHARED-parameter attention+MLP block ('S' — Zamba2's weight-shared
global block, 32H); the rest are Mamba2 ('M').  long_500k runs natively
(SSM state is O(1); the shared attention blocks use a sliding window in
the long-context serving variant).
"""
from repro.models.config import ModelConfig

_PATTERN = "".join(
    "S" if (i % 6) == 5 else "M" for i in range(81))

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab_size=32000,
    attn_type="gqa",
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    block_pattern=_PATTERN,
    ssm_state_dim=64,
    ssm_head_dim=64,
    ssm_expand=2,
    source="arXiv:2411.15242",
)
