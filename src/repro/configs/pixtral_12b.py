"""pixtral-12b [vlm] — Pixtral-ViT frontend (stub) + Mistral-Nemo-style
decoder backbone.  [hf:mistralai/Pixtral-12B-2409]

40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072.
The vision encoder + projector are a STUB per the assignment:
``input_specs`` supplies precomputed patch embeddings (d_model-sized)
for ``num_patch_tokens`` positions; the language backbone is real.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    d_ff=14336,
    vocab_size=131072,
    attn_type="gqa",
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=1e6,
    frontend="vision_stub",
    num_patch_tokens=256,
    source="hf:mistralai/Pixtral-12B-2409",
)
