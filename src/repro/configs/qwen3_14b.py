"""qwen3-14b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family, 14B scale]

40L d_model=5120 40H (GQA kv=8, head_dim=128) d_ff=17408 vocab=151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    d_ff=17408,
    vocab_size=151936,
    attn_type="gqa",
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B",
)
