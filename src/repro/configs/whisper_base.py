"""whisper-base [audio] — encoder-decoder with conv frontend (STUB).
[arXiv:2212.04356]

6 encoder + 6 decoder layers, d_model=512 8H d_ff=2048 vocab=51865.
The mel-spectrogram + conv feature extractor is a stub per the
assignment: ``input_specs`` supplies 1500 precomputed frame embeddings.
Decode shapes exercise the decoder's self-attention KV cache at the
assigned lengths (shape-level; real Whisper caps at 448 tokens —
noted deviation).  long_500k is skipped (enc-dec, see DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    d_ff=2048,
    vocab_size=51865,
    attn_type="gqa",
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    is_encoder_decoder=True,
    encoder_layers=6,
    encoder_seq=1500,
    frontend="audio_stub",
    source="arXiv:2212.04356",
)
