"""minicpm3-4b [dense] — Multi-head Latent Attention (MLA).
[hf:openbmb/MiniCPM3-4B]

62L d_model=2560 40H d_ff=6400 vocab=73448.  MLA dims follow the
MiniCPM3-4B model card: q_lora_rank=768, kv_lora_rank=256,
qk_nope/rope head dims 64/32, v_head_dim=64.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    num_heads=40,
    num_kv_heads=40,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    source="hf:openbmb/MiniCPM3-4B",
)
