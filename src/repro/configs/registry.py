"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import List

from repro.models.config import ModelConfig

_MODULES = {
    "pixtral-12b": "pixtral_12b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "whisper-base": "whisper_base",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "zamba2-7b": "zamba2_7b",
    "granite-3-8b": "granite_3_8b",
    "minitron-8b": "minitron_8b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen3-14b": "qwen3_14b",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(ARCH_IDS)
