"""Assemble EXPERIMENTS.md from run artifacts.

Sections:
  §Paper-repro — benchmark CSVs (fig2/table2/table3) if present;
  §Dry-run     — per (arch x shape x mesh) compile status + memory;
  §Roofline    — three terms, dominant bottleneck, useful-FLOPs ratio;
  §Perf        — the hypothesis->change->measure log (runs/perf_log.json,
                 maintained by the perf iterations).

    PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import csv
import glob
import json
import os

from repro.launch.roofline import load_results, markdown_table, \
    roofline_row

HW_NOTE = ("Hardware basis: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, "
           "50 GB/s/link ICI; 256 chips/pod (16x16), 512 for multi-pod "
           "(2x16x16). All per-device quantities from post-SPMD HLO "
           "with trip-count-aware loop accounting "
           "(src/repro/launch/hlo_analysis.py).")


def dryrun_table(runs: str, mesh: str) -> str:
    rows = []
    for r in load_results(runs, mesh):
        if r["status"] == "ok":
            m = r["memory"]
            fits = (m["argument_bytes"] + m["temp_bytes"]) / 2 ** 30
            rows.append(
                f"| {r['arch']} | {r['shape']} | ok | "
                f"{r['compile_s']:.0f}s | {fits:.1f} | "
                f"{r['collective_bytes'] / 2 ** 30:.1f} | "
                f"{r['flops'] / 1e12:.1f} |")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            rows.append(f"| {r['arch']} | {r['shape']} | {r['status']} | "
                        f"— | — | — | — | {reason}")
    hdr = ("| arch | shape | status | compile | args+temp GB/dev | "
           "coll GB/dev/step | TFLOP/dev/step |\n"
           "|---|---|---|---|---|---|---|\n")
    return hdr + "\n".join(rows)


def bench_section(bench_dir: str = "runs/bench") -> str:
    parts = []
    for name in ("fig2", "table2", "table3"):
        path = os.path.join(bench_dir, f"{name}.csv")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            rows = list(csv.reader(f))
        parts.append(f"### {name}\n")
        parts.append("| " + " | ".join(rows[0]) + " |")
        parts.append("|" + "---|" * len(rows[0]))
        for row in rows[1:]:
            parts.append("| " + " | ".join(
                x if not _isfloat(x) else f"{float(x):.4g}"
                for x in row) + " |")
        parts.append("")
    return "\n".join(parts) if parts else "_run `python -m benchmarks.run`_"


def _isfloat(x):
    try:
        float(x)
        return True
    except ValueError:
        return False


def perf_section(path: str = "runs/perf_log.json") -> str:
    if not os.path.exists(path):
        return "_no perf iterations recorded yet_"
    with open(path) as f:
        entries = json.load(f)
    out = []
    for e in entries:
        out.append(f"### {e['id']}: {e['title']}\n")
        out.append(f"- **Target**: {e['target']}")
        out.append(f"- **Hypothesis**: {e['hypothesis']}")
        out.append(f"- **Change**: {e['change']}")
        out.append(f"- **Before**: {e['before']}")
        out.append(f"- **After**: {e['after']}")
        out.append(f"- **Verdict**: {e['verdict']}\n")
    return "\n".join(out)


def main(runs="runs/dryrun", out_path="EXPERIMENTS.md"):
    parts = [
        "# EXPERIMENTS",
        "",
        HW_NOTE,
        "",
        "## §Paper-repro (Algorithm 1 simulation layer)",
        "",
        "Datasets are synthetic stand-ins (offline container; "
        "DESIGN.md §2). Validated: >95% overhead reduction (r-bar) at "
        "the paper's operating points; bisection+LP power control "
        "beats Dinkelbach / max-sum-rate on T_max under a latency "
        "budget (table3: 8 vs 1 rounds for every quantizer); "
        "mixed-resolution matches classic-FL accuracy on the 4-class "
        "task of tests/test_fl_loop.py (best acc 0.98 vs 0.73 at "
        "T=30, r-bar 94%). FINDING (accuracy-parity is "
        "spectrum-dependent): on the harder 10-class synthetic tasks "
        "below, mixed-resolution lags classic FL. Diagnostics: the "
        "realized threshold ratio rho = dw_q/||dw||_inf EQUALS lambda "
        "(no Lemma-1 gap — the bound is tight), but once training "
        "sharpens the delta spectrum the high-res fraction collapses "
        "(s ~ 1%%) and the scheme's by-design low-resolution "
        "reconstruction +-lambda/2 * ||dw||_inf exceeds the typical "
        "coordinate magnitude by orders; K=8->24 averaging does not "
        "cancel it (0.12 -> 0.18). The paper's real-CIFAR runs "
        "(K=20, T=100, Table II) report near-parity at s ~ 0.9%%; on "
        "our synthetic spectra the same operating point is unstable — "
        "a reproduction result worth flagging: the method's accuracy "
        "guarantee degrades exactly when its compression is best "
        "(small s), since per-coordinate noise is lambda/2 * "
        "||dw||_inf regardless of s.",
        "",
        bench_section(),
        "",
        "## §Dry-run",
        "",
        "### Single pod (16x16 = 256 chips)",
        "",
        dryrun_table(runs, "single"),
        "",
        "### Multi-pod (2x16x16 = 512 chips)",
        "",
        dryrun_table(runs, "multi"),
        "",
        "## §Roofline (single pod)",
        "",
        markdown_table(sorted(
            (roofline_row(r) for r in load_results(runs, "single")),
            key=lambda r: (r["arch"], r["shape"]))),
        "",
        "roofline-frac = compute-term / max(term): 1.0 means "
        "compute-bound at peak; useful-FLOPs = MODEL_FLOPS (6ND or "
        "2ND) / global HLO FLOPs — the gap is remat recompute, "
        "attention FLOPs (not in 6ND) and sharding redundancy.",
        "",
        "## §Perf",
        "",
        perf_section(),
        "",
    ]
    with open(out_path, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
