"""Input construction for every (arch x shape): concrete arrays for
smoke tests / examples, ShapeDtypeStructs for the dry-run.

Batch dict conventions (see models.transformer.forward / decode_step):
  train/prefill: {"tokens": [B, S_tok] i32}
    + vlm:   {"patch_embeds": [B, P, 1024] bf16}  (S_tok = S - P)
    + audio: {"frames": [B, enc_seq, 128] bf16}
  decode: {"tokens": [B, 1] i32, "cache_index": scalar i32} + cache
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.models.transformer import (AUDIO_FRONTEND_DIM,
                                      VISION_FRONTEND_DIM)

# dense (full-attention) archs run long_500k through this serving window
LONG_CONTEXT_WINDOW = 8192


def serving_window(cfg: ModelConfig, shape: InputShape) -> int:
    """Sliding-window size used for this (arch, shape); 0 = full."""
    if shape.name == "long_500k" and cfg.family not in ():
        # dense/moe/vlm archs need the sub-quadratic serving variant;
        # hybrid archs window their shared attention blocks too.
        if cfg.attn_type != "none":
            return LONG_CONTEXT_WINDOW
    return cfg.sliding_window


def supports(cfg: ModelConfig, shape: InputShape) -> bool:
    """Which (arch x shape) pairs run (skips are documented in
    DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and cfg.is_encoder_decoder:
        return False          # whisper: enc-dec, no 500k variant
    return True


def input_specs(cfg: ModelConfig, shape: InputShape,
                abstract: bool = True, seed: int = 0) -> Dict[str, Any]:
    """Model inputs for a train/prefill step (decode handled by
    cache_specs + token specs in the step builders)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    def make(shape_, dtype, hi=None):
        if abstract:
            return jax.ShapeDtypeStruct(shape_, dtype)
        rng = np.random.default_rng(seed)
        if np.issubdtype(dtype, np.integer):
            return jnp.asarray(rng.integers(0, hi, shape_), dtype)
        return jnp.asarray(rng.standard_normal(shape_), dtype)

    batch: Dict[str, Any] = {}
    if cfg.frontend == "vision_stub":
        P = cfg.num_patch_tokens
        batch["tokens"] = make((B, S - P), jnp.int32, cfg.vocab_size)
        batch["patch_embeds"] = make((B, P, VISION_FRONTEND_DIM), dt)
    elif cfg.frontend == "audio_stub":
        batch["tokens"] = make((B, S), jnp.int32, cfg.vocab_size)
        batch["frames"] = make((B, cfg.encoder_seq, AUDIO_FRONTEND_DIM), dt)
    else:
        batch["tokens"] = make((B, S), jnp.int32, cfg.vocab_size)
    return batch


def decode_token_specs(cfg: ModelConfig, shape: InputShape,
                       abstract: bool = True) -> Dict[str, Any]:
    B = shape.global_batch
    if abstract:
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "cache_index": jax.ShapeDtypeStruct((), jnp.int32)}
    return {"tokens": jnp.zeros((B, 1), jnp.int32),
            "cache_index": jnp.asarray(
                min(shape.seq_len,
                    serving_window(cfg, shape) or shape.seq_len) - 1,
                jnp.int32)}
