"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16 x 16 = 256 chips
("data", "model"); multi-pod: 2 x 16 x 16 = 512 chips
("pod", "data", "model").
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants (roofline basis)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
