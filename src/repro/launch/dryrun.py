import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production mesh, record memory / cost / collective statistics.

This file MUST set --xla_force_host_platform_device_count before any
other import (jax locks the device count at first init), hence the
unusual import order above.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch granite-3-8b --shape train_4k --mesh single \
        [--compressor mixed|none] [--out runs/dryrun]

One (arch, shape, mesh) per process: compile state is isolated and a
single failure cannot take down the sweep (launch/runner.py drives the
full matrix).
"""

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.dist import (CompressorConfig, TrainHParams,  # noqa: E402
                        build_decode_step, build_prefill_step,
                        build_train_step, decode_cache_shape,
                        decode_shardings, microbatch, param_shardings,
                        train_input_shardings)
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.inputs import input_specs, supports  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import init_model  # noqa: E402
from repro.models.config import INPUT_SHAPES  # noqa: E402


def abstract_params(cfg):
    """Parameter ShapeDtypeStructs — no allocation."""
    return jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg))


def count_params(params_shape) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(params_shape):
        n = 1
        for s in leaf.shape:
            n *= int(s)
        total += n
    return total


def active_param_count(cfg, params_shape) -> int:
    """MoE: experts contribute top_k / num_experts of their params."""
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    for path, leaf in flat:
        p = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                     for q in path)
        n = 1
        for s in leaf.shape:
            n *= s
        if "/moe/" in p and p.split("/")[-1] in ("w_gate", "w_up",
                                                 "w_down"):
            n = int(n * cfg.top_k / max(cfg.num_experts_padded, 1))
        total += n
    return total


def run_dryrun(arch: str, shape_name: str, multi_pod: bool,
               compressor: str = "mixed", s_budget: float = 0.01,
               bits: int = 4, l_local: int = 1) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if not supports(cfg, shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "documented skip (DESIGN.md §Arch-applicability)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    params_shape = abstract_params(cfg)
    n_params = count_params(params_shape)
    n_active = active_param_count(cfg, params_shape)

    if shape.kind == "train":
        hp = TrainHParams(L_local=l_local, compressor=CompressorConfig(
            kind=compressor, s_budget=s_budget, bits=bits))
        step = build_train_step(cfg, mesh, shape, hp)
        batch = microbatch(input_specs(cfg, shape, abstract=True),
                           hp.L_local)
        ps, bs = train_input_shardings(cfg, mesh, shape, params_shape,
                                       batch)
        lowered = jax.jit(step, in_shardings=(ps, bs)).lower(
            params_shape, batch)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        step = build_prefill_step(cfg, mesh, shape)
        batch = input_specs(cfg, shape, abstract=True)
        ps = param_shardings(params_shape, cfg, mesh)
        from repro.dist.sharding import batch_shardings
        bs = batch_shardings(batch, mesh, shape)
        lowered = jax.jit(step, in_shardings=(ps, bs)).lower(
            params_shape, batch)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:  # decode
        step = build_decode_step(cfg, mesh, shape)
        cache_shape = decode_cache_shape(cfg, shape)
        ps, cs, ts, isd = decode_shardings(cfg, mesh, shape, params_shape)
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        idx = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jax.jit(step, in_shardings=(ps, cs, ts, isd),
                          out_shardings=(None, cs)).lower(
            params_shape, cache_shape, tok, idx)
        model_flops = 2.0 * n_active * shape.global_batch
    t_lower = time.time() - t0

    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    stats = analyze_hlo(hlo)   # trip-count-aware per-device stats

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "compressor": compressor,
        "n_devices": mesh.devices.size,
        "n_params": n_params,
        "n_active_params": n_active,
        "model_flops": model_flops,
        "flops": stats["flops"],
        "hbm_bytes": stats["hbm_bytes"],
        "bytes_written": stats["bytes_written"],
        "param_bytes": stats["param_bytes"],
        "collective_bytes": stats["collective_bytes"],
        "collective_breakdown": stats["collective_breakdown"],
        "xla_flops_body_once": float(cost.get("flops", 0.0)),
        "xla_bytes_body_once": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    return result


def _run_one(arch, shape, mesh, compressor, s_budget, bits, out,
             l_local=1):
    os.makedirs(out, exist_ok=True)
    tag = f"{arch}__{shape}__{mesh}__{compressor}"
    path = os.path.join(out, tag + ".json")
    if os.path.exists(path):
        with open(path) as f:
            prev = json.load(f)
        if prev.get("status") in ("ok", "skipped"):
            print(f"[skip existing] {tag}")
            return prev
    t0 = time.time()
    try:
        res = run_dryrun(arch, shape, mesh == "multi",
                         compressor, s_budget, bits, l_local)
    except Exception as e:  # recorded, not raised: the sweep continues
        res = {"arch": arch, "shape": shape, "mesh": mesh,
               "compressor": compressor, "status": "error",
               "error": str(e)[-2000:],
               "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    brief = {k: res.get(k) for k in
             ("arch", "shape", "mesh", "status", "flops",
              "collective_bytes", "compile_s", "error")}
    brief["wall_s"] = round(time.time() - t0, 1)
    print(json.dumps(brief))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="one arch id, or comma list, or 'all'")
    ap.add_argument("--shape", default=None,
                    help="one shape, comma list, or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--compressor", default="mixed",
                    choices=["mixed", "none"])
    ap.add_argument("--s-budget", type=float, default=0.01)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--l-local", type=int, default=1)
    ap.add_argument("--out", default="runs/dryrun")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS
    archs = ARCH_IDS if args.arch in (None, "all") \
        else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape in (None, "all") \
        else args.shape.split(",")

    failures = 0
    for arch in archs:
        for shape in shapes:
            res = _run_one(arch, shape, args.mesh, args.compressor,
                           args.s_budget, args.bits, args.out,
                           args.l_local)
            failures += res.get("status") == "error"
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
