"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

XLA's ``cost_analysis()`` visits every computation ONCE — a
scan-over-layers body is counted a single time, which would understate
FLOPs/bytes/collectives by the layer count.  This walker multiplies
``while`` bodies by their ``known_trip_count`` backend_config (emitted
by XLA for counted loops, i.e. every lax.scan).

Per-device statistics extracted:
* flops            — 2 * prod(out) * prod(contracting dims) per dot
                     (matmul-dominated models; elementwise flops are
                     not counted — documented approximation);
* bytes_written    — sum of op output sizes at fusion granularity;
                     HBM traffic ~ 2x this (read+write), plus ENTRY
                     parameter reads, reported as hbm_bytes;
* collective_bytes — output sizes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute,
                     trip-multiplied, with a per-op breakdown.

All shapes in post-partitioning HLO are per-device, so every number
here is per-device per-step.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%([\w.\-]+), body=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BOOKKEEPING = ("parameter(", "get-tuple-element(", "tuple(", "constant(",
                "bitcast(", "after-all(", "partition-id(")


def _shape_list_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(m.group(1), 4)
    return total


def _first_shape(text: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) \
        else ()
    return m.group(1), dims


class _Comp:
    def __init__(self, name):
        self.name = name
        self.lines = []
        self.symbols: Dict[str, Tuple[str, Tuple[int, ...]]] = {}


def _parse_computations(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = _Comp(m.group(2))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(line.strip())
        dm = _DEF_RE.match(line.strip())
        if dm:
            shape = _first_shape(dm.group(2))
            if shape:
                cur.symbols[dm.group(1)] = shape
    return comps


def _dot_flops(rhs: str, symbols: Dict) -> float:
    """rhs: 'f32[a,b] dot(%x, %y), lhs_contracting_dims={1}, ...'"""
    out = _first_shape(rhs)
    if out is None:
        return 0.0
    out_elems = 1
    for d in out[1]:
        out_elems *= d
    m = re.search(r"dot\(%?([\w.\-]+)", rhs)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    if not m or not cm:
        return 0.0
    lhs_shape = symbols.get(m.group(1))
    if lhs_shape is None:
        return 2.0 * out_elems  # unknown operand: degenerate estimate
    contract = 1
    if cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_shape[1]):
                contract *= lhs_shape[1][i]
    return 2.0 * out_elems * contract


def analyze_hlo(text: str) -> Dict:
    comps = _parse_computations(text)
    memo: Dict[str, Dict] = {}

    def walk(name: str) -> Dict:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        stats = {"flops": 0.0, "bytes_written": 0.0,
                 "collective_bytes": 0.0,
                 "coll": defaultdict(float)}
        memo[name] = stats  # pre-insert (defensive vs cycles)
        if comp is None:
            return stats
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            rhs = dm.group(2) if dm else line

            # --- collectives ---
            for op in _COLL_OPS:
                if re.search(rf"\s{op}(-start)?\(", rhs) or \
                        rhs.startswith(f"{op}("):
                    head = rhs.split(op)[0]
                    b = _shape_list_bytes(head)
                    stats["collective_bytes"] += b
                    stats["coll"][op] += b
                    break

            # --- dots ---
            if re.search(r"\sdot\(", rhs):
                stats["flops"] += _dot_flops(rhs, comp.symbols)

            # --- sub-computations ---
            wm = _WHILE_RE.search(rhs)
            if wm and " while(" in rhs:
                tm = _TRIP_RE.search(rhs)
                trip = int(tm.group(1)) if tm else 1
                sub = walk(wm.group(2))
                cond = walk(wm.group(1))
                for k in ("flops", "bytes_written", "collective_bytes"):
                    stats[k] += trip * (sub[k] + cond[k])
                for k, v in sub["coll"].items():
                    stats["coll"][k] += trip * v
                continue
            cm = _CALLS_RE.search(rhs)
            if cm:
                sub = walk(cm.group(1))
                # fusion: flops/collectives from inside; bytes at the
                # fusion boundary only (sub-ops live in registers)
                stats["flops"] += sub["flops"]
                stats["collective_bytes"] += sub["collective_bytes"]
                for k, v in sub["coll"].items():
                    stats["coll"][k] += v

            # --- bytes written (fusion-boundary granularity) ---
            if dm and not any(b in rhs for b in _BOOKKEEPING):
                sh = _first_shape(rhs)
                if sh:
                    n = 1
                    for d in sh[1]:
                        n *= d
                    stats["bytes_written"] += n * _DTYPE_BYTES.get(
                        sh[0], 4)
        return stats

    entry_name = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line)
            entry_name = m.group(2)
            break
    if entry_name is None:
        raise ValueError("no ENTRY computation found")

    # ENTRY parameter bytes (weight/input reads)
    entry = comps[entry_name]
    param_bytes = 0
    for line in entry.lines:
        if "parameter(" in line:
            dm = _DEF_RE.match(line)
            if dm:
                param_bytes += _shape_list_bytes(dm.group(2).split("=")[0]
                                                 if "=" in dm.group(2)
                                                 else dm.group(2))

    total = walk(entry_name)
    return {
        "flops": total["flops"],
        "bytes_written": total["bytes_written"],
        "param_bytes": float(param_bytes),
        "hbm_bytes": 2.0 * total["bytes_written"] + param_bytes,
        "collective_bytes": total["collective_bytes"],
        "collective_breakdown": {k: v for k, v in total["coll"].items()},
    }
