"""Roofline report: three terms per (arch x shape x mesh) from the
dry-run sweep JSONs.

    compute    = per-device HLO FLOPs / 197 TFLOP/s  (bf16 peak)
    memory     = per-device HBM bytes / 819 GB/s
    collective = per-device collective bytes / 50 GB/s ICI link

All inputs are already per-device (post-SPMD HLO shapes), so no /chips
is applied — dividing the global quantities by chip count gives the
same numbers.  MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D
(inference) GLOBAL, compared against global HLO flops (per-device x
devices) to expose remat/redundancy waste.

    PYTHONPATH=src python -m repro.launch.roofline --runs runs/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def load_results(runs_dir: str, mesh: str = "single") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(runs_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            out.append(r)
    return out


def roofline_row(r: Dict) -> Dict:
    if r["status"] != "ok":
        return {"arch": r["arch"], "shape": r["shape"],
                "status": r["status"]}
    t_comp = r["flops"] / PEAK_FLOPS
    t_mem = r["hbm_bytes"] / HBM_BW
    t_coll = r["collective_bytes"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    hlo_global = r["flops"] * r["n_devices"]
    useful = r["model_flops"] / hlo_global if hlo_global else 0.0
    # fraction of the bound the compute term occupies = roofline frac
    return {
        "arch": r["arch"], "shape": r["shape"], "status": "ok",
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dominant,
        "roofline_fraction": t_comp / bound if bound else 0.0,
        "model_flops": r["model_flops"],
        "useful_flops_ratio": useful,
        "mem_args_gb": r["memory"]["argument_bytes"] / 2 ** 30,
        "mem_temp_gb": r["memory"]["temp_bytes"] / 2 ** 30,
        "collective_breakdown": r.get("collective_breakdown", {}),
    }


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:7.2f}s "
    return f"{x * 1e3:7.1f}ms"


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "roofline-frac | useful-FLOPs | args GB | temp GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute_s'])} | "
            f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
            f"**{r['dominant']}** | {r['roofline_fraction']:.2f} | "
            f"{r['useful_flops_ratio']:.2f} | {r['mem_args_gb']:.1f} | "
            f"{r['mem_temp_gb']:.1f} |")
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", default="runs/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default="runs/roofline.json")
    args = ap.parse_args()
    rows = [roofline_row(r) for r in load_results(args.runs, args.mesh)]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print(markdown_table(rows))
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=2)
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        collbound = [r for r in ok if r["dominant"] == "collective"]
        print(f"\nworst roofline fraction: {worst['arch']}/"
              f"{worst['shape']} ({worst['roofline_fraction']:.2f})")
        print(f"collective-bound pairs: "
              f"{[(r['arch'], r['shape']) for r in collbound]}")


if __name__ == "__main__":
    main()
