"""Fused Pallas mixed-resolution encode/decode — quantize-to-wire in
two streaming passes.

The paper's adaptive mixed-resolution quantization (eqs. 6-8,
``core/quantize/mixed_resolution.py``) is the per-user, per-round hot
path of the reproduction.  The pure-jnp reference makes ~8 full passes
over the d-element delta (abs/max/mask/min-where/round/three wheres),
materializes a dense f32 reconstruction, and leaves wire packing
(``core/quantize/packing.py``) as yet another downstream pass.  These
kernels collapse the whole encode into two streaming passes over VMEM
tiles and fuse the server-side decode with the multi-user weighted
reduction, so the dense reconstruction never exists anywhere:

* **pass A** (:func:`mixed_res_reduce`) — per-tile reductions of
  ``||x||_inf`` (grid phase 0), then the threshold-masked minimum
  ``dw_q`` and the high-resolution count ``dbar`` (grid phase 1, which
  needs the phase-0 max), tree-combined across the grid into one
  8-lane scalar row per user;
* **pass B** (:func:`mixed_res_emit`) — consumes the per-user scalar
  header and emits the packed wire format directly: uint32 sign-plane
  words, uint32 high-resolution mask words (both in the ``signpack``
  ``[W, 4]`` layout) and ``b``-bit magnitude codes packed
  ``32 // bw`` per word in the ``packing.pack_codes`` layout;
* **decode** (:func:`mixed_res_dequant_reduce`) — unpacks all G users'
  wire buffers tile-by-tile and reduces ``sum_g w_g * recon_g`` in one
  kernel; the per-user dense planes live only as one VMEM tile each.

Layout convention (same as ``quant_pack.py``): the flat f32 vector is
viewed as ``[W, 128]`` rows; sign/hi planes pack to ``[W, 4]`` uint32;
the code plane packs to ``[W, 4 * bw]`` uint32 where ``bw`` is the
code *storage* width — the smallest of {2, 4, 8, 16} that holds ``b``
bits (the paper's b = 10 stores in 16; the *accounted* payload uses
the true ``b``, see DESIGN.md section 9).  A leading user axis U rides
the grid, never a vmap.

TARGET is TPU; on CPU the kernels run under interpret=True (see
``ops.py``).  The jnp oracles live in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant_pack import BLOCK_ROWS

# wire-header lane assignment ([U, 8] f32 scalar rows).  Lane H_CHK
# carries the bitcast uint32 xor-fold checksum of the packed planes
# when WirePath(checksum=True); it is never read arithmetically (the
# bit pattern may alias a NaN) — decode and bit accounting consume
# lanes 0-3 only, so stamping it leaves both bit-for-bit unchanged.
H_INF, H_DWQ, H_STEP, H_DBAR, H_LAM, H_CHK = 0, 1, 2, 3, 4, 5
HEADER_LANES = 8

CODE_STORE_WIDTHS = (2, 4, 8, 16)


def code_width(b: int) -> int:
    """Storage width for b-bit codes: smallest of {2,4,8,16} >= b."""
    for w in CODE_STORE_WIDTHS:
        if w >= b:
            return w
    raise ValueError(f"wire kernels store codes in <= 16 bits, got b={b}")


def code_words_per_row(b: int) -> int:
    """uint32 words per 128-lane row of the packed code plane."""
    return 128 * code_width(b) // 32


def _valid_mask(i, bm: int, d_valid: int):
    """[bm, 128] bool — element's flat index within the real (unpadded)
    vector.  ``d_valid`` is static; callers skip the mask entirely when
    the vector fills its padded view."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 128), 0)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (bm, 128), 1)
    flat = (i * bm + rows) * 128 + lanes
    return flat < d_valid


# ------------------------------------------------------------ pass A
def _reduce_kernel(x_ref, out_ref, *, lam: float, bm: int, d_valid: int,
                   masked: bool):
    """Grid (U, 2, T).  Phase 0 accumulates ||x||_inf; phase 1 (which
    reads the phase-0 result from the revisited output row) accumulates
    the threshold-masked min ``dw_q`` and the high-res count ``dbar``.
    out_ref: [1, 8] f32 per user — revisited across (phase, tile), so
    it stays resident in VMEM for the whole per-user reduction."""
    ph = pl.program_id(1)
    i = pl.program_id(2)
    absx = jnp.abs(x_ref[0])

    @pl.when((ph == 0) & (i == 0))
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(ph == 0)
    def _():
        out_ref[0, H_INF] = jnp.maximum(out_ref[0, H_INF], jnp.max(absx))

    @pl.when(ph == 1)
    def _():
        @pl.when(i == 0)
        def _():
            out_ref[0, H_DWQ] = jnp.inf

        inf = out_ref[0, H_INF]
        safe_inf = jnp.where(inf > 0, inf, 1.0)
        # the same per-element division the jnp reference uses (NOT
        # absx >= lam * inf, which rounds differently)
        hi = (absx / safe_inf) >= lam
        if masked:
            hi = hi & _valid_mask(i, bm, d_valid)
        out_ref[0, H_DWQ] = jnp.minimum(
            out_ref[0, H_DWQ], jnp.min(jnp.where(hi, absx, jnp.inf)))
        out_ref[0, H_DBAR] = out_ref[0, H_DBAR] + jnp.sum(
            hi.astype(jnp.float32))


def mixed_res_reduce(x: jnp.ndarray, lam: float, d_valid: int, *,
                     interpret: bool = False,
                     block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """x: [U, W, 128] f32 -> stats [U, 8] f32.

    Lane H_INF holds ``||x||_inf``, H_DWQ the raw threshold-masked min
    (``+inf`` when no element clears the threshold — callers map it to
    0 like the jnp reference), H_DBAR the high-resolution count (exact
    in f32 for d < 2**24).  ``d_valid`` is the unpadded length; pad
    elements never enter the phase-1 mask."""
    U, W, _ = x.shape
    bm = min(block_rows, W)
    assert W % bm == 0, (W, bm)
    if not (0 < d_valid <= W * 128):
        raise ValueError(f"d_valid={d_valid} outside (0, {W * 128}]")
    if d_valid >= 2 ** 24:
        raise ValueError("f32 dbar accumulator is exact only to 2**24")
    kernel = functools.partial(
        _reduce_kernel, lam=float(lam), bm=bm, d_valid=int(d_valid),
        masked=d_valid != W * 128)
    return pl.pallas_call(
        kernel,
        grid=(U, 2, W // bm),
        in_specs=[pl.BlockSpec((1, bm, 128), lambda u, p, i: (u, i, 0))],
        out_specs=pl.BlockSpec((1, HEADER_LANES),
                               lambda u, p, i: (u, 0)),
        out_shape=jax.ShapeDtypeStruct((U, HEADER_LANES), jnp.float32),
        interpret=interpret,
    )(x)


# ------------------------------------------------------------ pass B
def _emit_kernel(x_ref, head_ref, signs_ref, hi_ref, codes_ref, *,
                 bw: int, levels: int, anchored: bool, bm: int,
                 d_valid: int, masked: bool):
    """Grid (U, T): consume the scalar header, emit the wire tile."""
    i = pl.program_id(1)
    x = x_ref[0]
    absx = jnp.abs(x)
    inf = head_ref[0, H_INF]
    dw_q = head_ref[0, H_DWQ]
    step = head_ref[0, H_STEP]
    safe_step = jnp.where(step > 0, step, 1.0)
    if anchored:
        hi = absx >= dw_q                       # static-budget rule
    else:
        safe_inf = jnp.where(inf > 0, inf, 1.0)
        hi = (absx / safe_inf) >= head_ref[0, H_LAM]   # eq. (6)
    if masked:
        hi = hi & _valid_mask(i, bm, d_valid)

    # b-bit magnitude code on the [dw_q, inf] grid; low-res elements
    # would produce negative codes — masked to 0 before the uint cast.
    # The clamp to the grid top is a no-op when the header's inf is the
    # true max (codes never exceed `levels` then), but an anchored
    # header from an approximate top-k (jax.lax.approx_max_k) can
    # underestimate inf — an unclamped code would then spill shifted
    # bits into NEIGHBORING code slots and corrupt other elements;
    # clamped, the overshoot stays element-local (mag caps at inf),
    # like the jnp reference's behaviour.
    code = jnp.round((absx - dw_q) / safe_step)
    code = jnp.minimum(jnp.where(hi, code, 0.0),
                       float(levels)).astype(jnp.uint32)

    shifts32 = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    sbits = (x > 0).astype(jnp.uint32).reshape(bm, 4, 32)
    signs_ref[0] = jnp.sum(sbits << shifts32, axis=-1, dtype=jnp.uint32)
    hbits = hi.astype(jnp.uint32).reshape(bm, 4, 32)
    hi_ref[0] = jnp.sum(hbits << shifts32, axis=-1, dtype=jnp.uint32)

    per = 32 // bw                              # codes per uint32 word
    cshift = (jnp.arange(per, dtype=jnp.uint32) * bw)[None, None, :]
    cw = code.reshape(bm, 128 * bw // 32, per)
    codes_ref[0] = jnp.sum(cw << cshift, axis=-1, dtype=jnp.uint32)


def mixed_res_emit(x: jnp.ndarray, head: jnp.ndarray, b: int,
                   d_valid: int, *, anchored: bool = False,
                   interpret: bool = False,
                   block_rows: int = BLOCK_ROWS):
    """x: [U, W, 128] f32, head: [U, 8] f32 -> packed wire planes
    (signs [U, W, 4], hi [U, W, 4], codes [U, W, 4*bw]) uint32.

    ``anchored=False`` uses the paper's threshold rule
    ``|x|/||x||_inf >= lambda`` (header lane H_LAM); ``anchored=True``
    uses the static-budget rule ``|x| >= dw_q`` (repro.dist)."""
    U, W, _ = x.shape
    bm = min(block_rows, W)
    assert W % bm == 0, (W, bm)
    bw = code_width(b)
    cpr = code_words_per_row(b)
    kernel = functools.partial(
        _emit_kernel, bw=bw, levels=2 ** b - 1, anchored=anchored,
        bm=bm, d_valid=int(d_valid), masked=d_valid != W * 128)
    return pl.pallas_call(
        kernel,
        grid=(U, W // bm),
        in_specs=[pl.BlockSpec((1, bm, 128), lambda u, i: (u, i, 0)),
                  pl.BlockSpec((1, HEADER_LANES), lambda u, i: (u, 0))],
        out_specs=[pl.BlockSpec((1, bm, 4), lambda u, i: (u, i, 0)),
                   pl.BlockSpec((1, bm, 4), lambda u, i: (u, i, 0)),
                   pl.BlockSpec((1, bm, cpr), lambda u, i: (u, i, 0))],
        out_shape=[jax.ShapeDtypeStruct((U, W, 4), jnp.uint32),
                   jax.ShapeDtypeStruct((U, W, 4), jnp.uint32),
                   jax.ShapeDtypeStruct((U, W, cpr), jnp.uint32)],
        interpret=interpret,
    )(x, head)


# ------------------------------------------------------------- decode
def _dequant_reduce_kernel(signs_ref, hi_ref, codes_ref, head_ref,
                           w_ref, *rest, bw: int, bm: int):
    """All G users' wire tiles -> one weighted-reduced f32 tile.  The
    per-user dense reconstruction exists only as this VMEM tile.  With
    an ``acc`` operand (cohort chunking) the tile is added on top of
    the carried accumulator tile instead of overwriting it."""
    if len(rest) == 2:
        acc_ref, out_ref = rest
    else:
        acc_ref, (out_ref,) = None, rest
    G = signs_ref.shape[0]
    shifts32 = jnp.arange(32, dtype=jnp.uint32)[None, None, None, :]
    one = jnp.uint32(1)

    sbits = (signs_ref[...][..., None] >> shifts32) & one   # [G,bm,4,32]
    signs = sbits.astype(jnp.float32).reshape(G, bm, 128) * 2.0 - 1.0
    hbits = (hi_ref[...][..., None] >> shifts32) & one
    hi = hbits.reshape(G, bm, 128) > 0

    per = 32 // bw
    cshift = (jnp.arange(per, dtype=jnp.uint32) * bw)[None, None, None, :]
    cmask = jnp.uint32((1 << bw) - 1)
    code = ((codes_ref[...][..., None] >> cshift) & cmask).astype(
        jnp.float32).reshape(G, bm, 128)

    dw_q = head_ref[:, H_DWQ].reshape(G, 1, 1)
    step = head_ref[:, H_STEP].reshape(G, 1, 1)
    # eq. (7)/(8): b-bit grid magnitude on the hi support, dw_q/2 off it
    mag = jnp.where(hi, dw_q + code * step, dw_q * 0.5)
    recon = signs * mag
    red = jnp.einsum(
        "g,gwl->wl", w_ref[...].reshape(G), recon,
        preferred_element_type=jnp.float32)
    out_ref[...] = red if acc_ref is None else acc_ref[...] + red


def mixed_res_dequant_reduce(signs: jnp.ndarray, hi: jnp.ndarray,
                             codes: jnp.ndarray, head: jnp.ndarray,
                             weights: jnp.ndarray, b: int, *,
                             acc: jnp.ndarray | None = None,
                             interpret: bool = False,
                             block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """signs/hi: [G, W, 4] u32, codes: [G, W, 4*bw] u32, head: [G, 8]
    f32, weights: [G] f32 -> [W, 128] f32 = sum_g w_g * deq(wire_g).

    Fuses per-user wire decoding with the weighted multi-user reduce:
    the G dense f32 reconstruction planes never hit HBM.  ``acc``
    ([W, 128] f32, optional) adds the reduce on top of a carried
    accumulator tile-by-tile, so cohort chunks of a large user axis
    fold through one resident plane (DESIGN.md §12: the kernel's
    chunked sum is ``acc + einsum(chunk)``, ulp-level order-sensitive
    across chunkings — the jnp oracle's sequential fold is the
    chunking-invariant reference)."""
    G, W, _ = signs.shape
    bm = min(block_rows, W)
    assert W % bm == 0, (W, bm)
    bw = code_width(b)
    cpr = code_words_per_row(b)
    assert codes.shape == (G, W, cpr), (codes.shape, cpr)
    kernel = functools.partial(_dequant_reduce_kernel, bw=bw, bm=bm)
    in_specs = [pl.BlockSpec((G, bm, 4), lambda i: (0, i, 0)),
                pl.BlockSpec((G, bm, 4), lambda i: (0, i, 0)),
                pl.BlockSpec((G, bm, cpr), lambda i: (0, i, 0)),
                pl.BlockSpec((G, HEADER_LANES), lambda i: (0, 0)),
                pl.BlockSpec((G, 1), lambda i: (0, 0))]
    args = [signs, hi, codes, head, weights.reshape(G, 1)]
    if acc is not None:
        assert acc.shape == (W, 128), acc.shape
        in_specs.append(pl.BlockSpec((bm, 128), lambda i: (i, 0)))
        args.append(acc.astype(jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=(W // bm,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((W, 128), jnp.float32),
        interpret=interpret,
    )(*args)
