"""WirePath — the single wire-path specification shared by sim and dist.

Before PR 8 the wire-path choice was spread over three stringly-typed
knobs that named the SAME underlying decision in different vocabularies:

* ``EngineConfig.aggregation`` — ``"dense" | "signplane" | "wire"``
  (which aggregation plane the sim engine's fused step runs);
* ``CompressorConfig.wire_path`` — ``"fused" | "reference"`` (which
  realization of the packed exchange repro.dist runs);
* per-call ``interpret`` / ``use_kernel`` picks in ``kernels/ops.py``
  (which lowering of the packed plane executes).

:class:`WirePath` owns all three axes in one frozen spec, plus the
streaming-cohort knobs introduced with it:

* ``plane``    — what moves at the fan-in: ``"dense"`` f32 recons,
  ``"signplane"`` packed 1-bit planes + dense high-res correction, or
  ``"packed"`` the full sign/hi/code wire buffers (DESIGN.md §9);
* ``lowering`` — which implementation of the packed plane runs:
  ``"auto"`` (Pallas kernels on TPU, the jnp ref-oracle composition
  elsewhere — today's default behaviour), ``"kernel"``, ``"reference"``;
* ``reduce``   — how multi-peer buffers meet in repro.dist manual mode:
  ``"gather"`` (all_gather the packed buffers, one fused decode) or
  ``"ring"`` (G-1 ``collective_permute`` hops, one packed buffer
  resident per hop, folded via the chunked accumulate — DESIGN.md §12);
* ``cohort_size`` — sim engine user-axis streaming: ``None`` keeps the
  fully vectorized step (bit-for-bit today's path); an int C scans the
  K users in cohorts of C so no ``[K, d]`` buffer ever exists;
* ``clusters`` — two-level hierarchy: the K users are partitioned into
  this many AP-cluster groups, each aggregated on-device into a partial
  ``[d]`` plane, combined host-side (the cell-free topology's sharding
  story for the 10^4-10^5-user axis).

The legacy strings keep working through :func:`from_aggregation` /
:func:`from_wire_path` (DeprecationWarning; tests/test_cohort.py pins
the shims).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax

PLANES = ("dense", "signplane", "packed")
LOWERINGS = ("auto", "kernel", "reference")
REDUCES = ("gather", "ring")

# The packed wire format counts high-res entries (dbar) and folds the
# weighted dequant in f32 accumulators: exact only while every integer
# involved stays below 2**24 (f32 mantissa).  Shared guard — the sim
# engine constructor, the fused encoder, and repro.dist's
# CompressorConfig paths all call it so large-d misuse fails loudly
# everywhere instead of silently miscounting.
PACKED_DIM_LIMIT = 2 ** 24


def check_packed_dim(d: int, *, where: str = "the packed wire plane"
                     ) -> None:
    """Raise unless ``d`` is exactly countable in the f32 wire headers."""
    if d >= PACKED_DIM_LIMIT:
        raise ValueError(
            f"{where} requires d < 2**24 (got d={d}): the dbar count and "
            "weighted dequant accumulate in f32, which is exact only below "
            "2**24. Shard the vector (repro.dist), use per-layer budget "
            "segments under 2**24 each, or the signplane/dense plane.")

# legacy vocabulary -> plane
_AGGREGATION_TO_PLANE = {"dense": "dense", "signplane": "signplane",
                         "wire": "packed"}
_WIRE_PATH_TO_PLANE = {"fused": "packed", "reference": "signplane"}


@dataclasses.dataclass(frozen=True)
class WirePath:
    """One wire-path spec for both the sim engine and repro.dist."""
    plane: str = "packed"        # "dense" | "signplane" | "packed"
    lowering: str = "auto"       # "auto" | "kernel" | "reference"
    reduce: str = "gather"       # "gather" | "ring" (dist manual mode)
    cohort_size: Optional[int] = None    # sim: stream K in cohorts of C
    clusters: int = 1            # sim: AP-cluster partial aggregates
    # Optional repro.core.quantize.LayerBudget — per-leaf-group
    # mixed-resolution budgets (DESIGN.md §13).  Typed loosely to keep
    # kernels import-independent of core.quantize; validate() duck-checks
    # the contract.  LayerBudget.uniform() (is_uniform=True) must behave
    # exactly like None: consumers keep the single-segment global path.
    budget: Optional[object] = None
    # Stamp an xor-fold integrity word over the packed uint32 planes
    # into header lane H_CHK at encode, verified at decode by the
    # resilience layer (DESIGN.md §14).  Stamping touches no lane the
    # decode or bit accounting reads, so checksum=True alone is
    # bit-for-bit on params, payload bits and metrics.
    checksum: bool = False

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        if self.plane not in PLANES:
            raise ValueError(f"unknown wire plane {self.plane!r}; "
                             f"have {PLANES}")
        if self.lowering not in LOWERINGS:
            raise ValueError(f"unknown wire lowering {self.lowering!r}; "
                             f"have {LOWERINGS}")
        if self.reduce not in REDUCES:
            raise ValueError(f"unknown wire reduce {self.reduce!r}; "
                             f"have {REDUCES}")
        if self.cohort_size is not None and self.cohort_size < 1:
            raise ValueError(
                f"cohort_size must be >= 1 or None, got {self.cohort_size}")
        if self.clusters < 1:
            raise ValueError(f"clusters must be >= 1, got {self.clusters}")
        if self.cohort_size is not None and self.plane != "packed":
            raise ValueError(
                "cohort streaming folds packed wire planes; use "
                f"plane='packed' (got plane={self.plane!r})")
        if self.clusters > 1 and self.cohort_size is None:
            raise ValueError(
                "clusters > 1 partially aggregates cohort streams; set "
                "cohort_size as well")
        if self.budget is not None and not (
                hasattr(self.budget, "segments_for")
                and hasattr(self.budget, "is_uniform")):
            raise ValueError(
                "budget must be a repro.core.quantize.LayerBudget "
                f"(got {type(self.budget).__name__})")
        if self.checksum and self.plane != "packed":
            raise ValueError(
                "checksum folds the packed uint32 wire planes; use "
                f"plane='packed' (got plane={self.plane!r})")
        if self.budget is not None and not self.budget.is_uniform:
            if self.plane == "signplane":
                raise ValueError(
                    "per-layer budgets are not supported on the signplane "
                    "plane; use plane='packed' or plane='dense'")
            if self.streaming or self.clusters > 1:
                raise ValueError(
                    "per-layer budgets do not compose with cohort "
                    "streaming or AP clusters yet; drop cohort_size/"
                    "clusters or use LayerBudget.uniform()")

    @property
    def effective_budget(self):
        """The budget when it changes anything, else None — uniform
        budgets route the pre-existing global path bit-for-bit."""
        if self.budget is not None and not self.budget.is_uniform:
            return self.budget
        return None

    # ------------------------------------------------ lowering resolution
    def use_kernel(self) -> bool:
        """True when the packed plane runs the Pallas kernels (the TPU
        target); False runs the jnp ref-oracle composition under the
        caller's jit — what CPU call sites actually execute."""
        if self.lowering == "auto":
            return jax.default_backend() == "tpu"
        return self.lowering == "kernel"

    def interpret(self) -> bool:
        """Pallas interpret mode — the correctness harness everywhere
        but real TPU hardware."""
        return jax.default_backend() != "tpu"

    @property
    def streaming(self) -> bool:
        """True when the sim engine scans user cohorts instead of
        vectorizing the full K axis."""
        return self.cohort_size is not None


def from_aggregation(name: str, *, warn: bool = True) -> WirePath:
    """Map a legacy ``EngineConfig.aggregation`` string to a WirePath.

    ``warn=True`` emits the deprecation warning (the shim for old call
    sites); resolvers that merely translate a still-supported default
    pass ``warn=False``."""
    if name not in _AGGREGATION_TO_PLANE:
        raise ValueError(f"unknown aggregation {name!r}; "
                         f"have {tuple(_AGGREGATION_TO_PLANE)}")
    if warn:
        warnings.warn(
            f"EngineConfig.aggregation={name!r} is deprecated; pass "
            f"EngineConfig(wire=WirePath(plane="
            f"{_AGGREGATION_TO_PLANE[name]!r}))",
            DeprecationWarning, stacklevel=2)
    return WirePath(plane=_AGGREGATION_TO_PLANE[name])


def from_wire_path(name: str, *, warn: bool = True) -> WirePath:
    """Map a legacy ``CompressorConfig.wire_path`` string to a WirePath."""
    if name not in _WIRE_PATH_TO_PLANE:
        raise ValueError(f"unknown wire_path {name!r}; "
                         f"have {tuple(_WIRE_PATH_TO_PLANE)}")
    if warn:
        warnings.warn(
            f"CompressorConfig.wire_path={name!r} is deprecated; pass "
            f"CompressorConfig(wire=WirePath(plane="
            f"{_WIRE_PATH_TO_PLANE[name]!r}))",
            DeprecationWarning, stacklevel=2)
    return WirePath(plane=_WIRE_PATH_TO_PLANE[name])
