"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) and
False on TPU, so the same call sites work in both environments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from typing import NamedTuple

from repro import obs as _obs

from . import ref as _ref
from .flash_decode import flash_decode as _flash_decode
from .mixed_res import (H_CHK, H_DBAR, H_DWQ, H_INF, H_LAM, H_STEP,
                        mixed_res_dequant_reduce, mixed_res_emit,
                        mixed_res_reduce)
from .quant_pack import sign_dequant_reduce as _sdr
from .quant_pack import signpack as _signpack
from .wire import WirePath, check_packed_dim


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _default_use_kernel(use_kernel: bool | None) -> bool:
    """The fused wire path has two lowerings of the same streaming
    pipeline: the Pallas kernels (the TPU target; run under
    interpret=True on CPU — the parity suite pins them bit-identical
    to the jnp lowering) and the jnp composition of the ref.py oracles
    under the caller's jit (what CPU call sites actually execute —
    interpret mode is a correctness harness, not a fast path)."""
    if use_kernel is None:
        return jax.default_backend() == "tpu"
    return use_kernel


def _resolve_lowering(path: WirePath | None, interpret: bool | None,
                      use_kernel: bool | None) -> tuple:
    """One shared lowering decision for every wire op: a WirePath spec
    wins; the legacy per-call ``interpret``/``use_kernel`` booleans are
    honored when no spec is given (they remain the kernel test suite's
    harness knobs)."""
    if path is not None:
        return (path.interpret() if interpret is None else interpret,
                path.use_kernel() if use_kernel is None else use_kernel)
    return (_default_interpret() if interpret is None else interpret,
            _default_use_kernel(use_kernel))


@functools.partial(jax.jit, static_argnames=("interpret",))
def signpack_op(x: jnp.ndarray, interpret: bool | None = None
                ) -> jnp.ndarray:
    """Pack the sign plane of a flat f32 vector.

    x: [d] f32 with d % 128 == 0  ->  [d/32] uint32 (viewed flat)."""
    interp = _default_interpret() if interpret is None else interpret
    words = _signpack(x.reshape(-1, 128), interpret=interp)
    return words.reshape(-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sign_dequant_reduce_op(words: jnp.ndarray, scales: jnp.ndarray,
                           interpret: bool | None = None) -> jnp.ndarray:
    """words: [G, d/32] u32, scales: [G] -> [d] f32 weighted sign sum."""
    interp = _default_interpret() if interpret is None else interpret
    G = words.shape[0]
    out = _sdr(words.reshape(G, -1, 4), scales, interpret=interp)
    return out.reshape(-1)


def sign_pad_len(d: int) -> int:
    """Padded length for viewing a flat d-vector as signpack's [W, 128]
    rows with a valid block partition: W = ceil(d/128), padded up to a
    multiple of 256 rows once W exceeds one block."""
    rows = -(-d // 128)
    if rows > 256 and rows % 256:
        rows = -(-rows // 256) * 256
    return rows * 128


def packed_sign_weighted_sum(flat: jnp.ndarray, scales: jnp.ndarray,
                             interpret: bool | None = None) -> jnp.ndarray:
    """flat: [G, d] f32, scales: [G] f32 -> [d] f32 equal to
    ``sum_g scales_g * sign(flat_g)`` with sign(x) = +1 iff x > 0.

    Routes through the packed wire format: one signpack launch bit-packs
    all G sign planes ([G*W, 128] f32 -> uint32 words, the arrays a
    multi-peer aggregation actually moves), then sign_dequant_reduce
    fuses per-peer unpacking with the scale-weighted reduction.  Not
    jitted here — call sites trace it into their own jitted steps.
    """
    interp = _default_interpret() if interpret is None else interpret
    G, d = flat.shape
    d_pad = sign_pad_len(d)
    if d_pad != d:
        flat = jnp.pad(flat, ((0, 0), (0, d_pad - d)))
    rows = d_pad // 128
    # the G planes are stacked into one [G*rows, 128] launch, so the
    # block size must divide the per-plane row count (rows <= 256 after
    # sign_pad_len only when it IS the whole plane) — G*rows alone need
    # not be a multiple of the default 256-row block
    bm = rows if rows <= 256 else 256
    words = _signpack(flat.reshape(-1, 128), interpret=interp,
                      block_rows=bm)
    words = words.reshape(G, rows, 4)
    out = _sdr(words, scales.astype(jnp.float32), interpret=interp,
               block_rows=bm)
    return out.reshape(-1)[:d]


# ------------------------------------------------- fused mixed-res wire
class MixedResWire(NamedTuple):
    """Packed wire buffers for U stacked deltas (what a multi-peer
    aggregation actually transmits): sign plane + high-res mask plane
    ([U, W, 4] u32, signpack layout), b-bit magnitude codes
    ([U, W, 4*bw] u32, packing.pack_codes layout) and the per-user
    scalar header row ([U, 8] f32 — inf, dw_q, step, dbar, lambda)."""
    signs: jnp.ndarray
    hi: jnp.ndarray
    codes: jnp.ndarray
    head: jnp.ndarray


def _tap_wire(name: str, users: int, dense_bytes: int,
              wire: "MixedResWire") -> None:
    """Stream the wire path's traffic to the active obs session: bytes
    in/out per fused encode/decode launch (static shape products, so
    the tap carries no device values beyond the callback token; the
    report CLI turns totals into attained vs roofline bandwidth).
    Trace-time gated — stages nothing without a session."""
    if not _obs.jit_stream_enabled():
        return
    packed = sum(int(a.size) * a.dtype.itemsize
                 for a in (wire.signs, wire.hi, wire.codes, wire.head))
    if name == "wire.encode":
        _obs.jit_tap(name, {"bytes_in": dense_bytes,
                            "bytes_out": packed, "users": users})
    else:
        _obs.jit_tap(name, {"bytes_in": packed,
                            "bytes_out": dense_bytes, "users": users})


def wire_view(flat: jnp.ndarray):
    """[U, d] f32 -> zero-padded [U, W, 128] rows (W per sign_pad_len,
    so the kernels' block partition is always valid)."""
    U, d = flat.shape
    d_pad = sign_pad_len(d)
    if d_pad != d:
        flat = jnp.pad(flat, ((0, 0), (0, d_pad - d)))
    return flat.reshape(U, d_pad // 128, 128)


def wire_checksum(wire: "MixedResWire") -> jnp.ndarray:
    """[U] uint32 xor-fold over every packed uint32 word of each user's
    sign/hi/code planes — the integrity word carried in header lane
    ``H_CHK`` when ``WirePath(checksum=True)``.

    Both lowerings share the jnp fold (ref.xor_fold_words_ref): the
    planes are bit-exact across Pallas/interpret/jnp, and xor is
    order-free, so the checksum is lowering-invariant by construction.
    Each plane folds separately (then the three [U] words xor) — a
    concatenated [U, n] staging copy would double the checksum's
    memory traffic against its <5% wire-path overhead budget."""
    U = wire.signs.shape[0]
    chk = _ref.xor_fold_words_ref(wire.signs.reshape(U, -1))
    chk ^= _ref.xor_fold_words_ref(wire.hi.reshape(U, -1))
    return chk ^ _ref.xor_fold_words_ref(wire.codes.reshape(U, -1))


def stamp_checksum(wire: "MixedResWire") -> "MixedResWire":
    """Store the xor-fold checksum in header lane H_CHK (bitcast to the
    f32 header row — the bit pattern is never read arithmetically)."""
    chk = jax.lax.bitcast_convert_type(wire_checksum(wire), jnp.float32)
    return wire._replace(head=wire.head.at[:, H_CHK].set(chk))


def verify_wire(wire: "MixedResWire") -> jnp.ndarray:
    """[U] bool — recompute the plane checksum and compare against the
    header word stamped at encode.  Only meaningful for wires produced
    under ``WirePath(checksum=True)``; jit-safe (no host sync), so
    callers fold the verdict into quarantine masks inside the step."""
    stored = jax.lax.bitcast_convert_type(
        wire.head[:, H_CHK].astype(jnp.float32), jnp.uint32)
    return wire_checksum(wire) == stored


def mixed_res_encode(flat: jnp.ndarray, lambda_: float, b: int, *,
                     interpret: bool | None = None,
                     use_kernel: bool | None = None,
                     path: WirePath | None = None) -> MixedResWire:
    """Threshold-rule (paper eq. 6) encode of U stacked deltas straight
    to the packed wire format — two streaming passes, no dense recon.

    flat: [U, d] f32.  Not jitted here; call sites trace it into their
    own jitted steps."""
    flat = flat.astype(jnp.float32)
    U, d = flat.shape
    # both lowerings accumulate the high-res count in f32 — refuse
    # identically on every backend via the shared WirePath-level guard
    check_packed_dim(d, where="mixed_res_encode")
    x3 = wire_view(flat)
    interp, kern = _resolve_lowering(path, interpret, use_kernel)
    if kern:
        stats = mixed_res_reduce(x3, lambda_, d, interpret=interp)
    else:
        stats = _ref.mixed_res_reduce_ref(x3, lambda_, d)
    # scalar epilogue — identical op sequence to the jnp reference
    inf = stats[:, H_INF]
    dw_q_raw = stats[:, H_DWQ]
    dw_q = jnp.where(jnp.isfinite(dw_q_raw), dw_q_raw, 0.0)
    step = (inf - dw_q) / (2 ** b - 1)
    head = stats.at[:, H_DWQ].set(dw_q).at[:, H_STEP].set(step) \
                .at[:, H_LAM].set(lambda_)
    if kern:
        signs, hi, codes = mixed_res_emit(x3, head, b, d,
                                          interpret=interp)
    else:
        signs, hi, codes = _ref.mixed_res_emit_ref(x3, head, b, d)
    wire = MixedResWire(signs=signs, hi=hi, codes=codes, head=head)
    if path is not None and path.checksum:
        wire = stamp_checksum(wire)
    _tap_wire("wire.encode", int(U), flat.size * 4, wire)
    return wire


def mixed_res_encode_anchored(flat: jnp.ndarray, inf: jnp.ndarray,
                              dw_q: jnp.ndarray, b: int, *,
                              interpret: bool | None = None,
                              use_kernel: bool | None = None,
                              path: WirePath | None = None
                              ) -> MixedResWire:
    """Static-budget (``|x| >= dw_q``) encode used by repro.dist: the
    grid anchor comes from an upstream top-k, so only the emit pass
    runs.  flat: [U, d]; inf/dw_q: [U] f32."""
    flat = flat.astype(jnp.float32)
    U, d = flat.shape
    x3 = wire_view(flat)
    step = (inf - dw_q) / (2 ** b - 1)
    head = jnp.zeros((U, 8), jnp.float32)
    head = head.at[:, H_INF].set(inf).at[:, H_DWQ].set(dw_q) \
               .at[:, H_STEP].set(step)
    interp, kern = _resolve_lowering(path, interpret, use_kernel)
    if kern:
        signs, hi, codes = mixed_res_emit(x3, head, b, d, anchored=True,
                                          interpret=interp)
    else:
        signs, hi, codes = _ref.mixed_res_emit_ref(x3, head, b, d,
                                                   anchored=True)
    wire = MixedResWire(signs=signs, hi=hi, codes=codes, head=head)
    if path is not None and path.checksum:
        wire = stamp_checksum(wire)
    _tap_wire("wire.encode", int(U), flat.size * 4, wire)
    return wire


def mixed_res_wire_reduce(wire: MixedResWire, weights: jnp.ndarray,
                          b: int, d: int, *,
                          acc: jnp.ndarray | None = None,
                          interpret: bool | None = None,
                          use_kernel: bool | None = None,
                          path: WirePath | None = None) -> jnp.ndarray:
    """Fused decode + weighted reduce: sum_g weights_g * deq(wire_g)
    -> [d] f32, entirely from the packed buffers.

    ``acc`` ([d] f32, optional) chains the reduce across cohort chunks:
    the result is ``acc + sum_g w_g * deq(wire_g)`` folded so the
    chunked accumulation over a partitioned user axis reproduces the
    one-shot reduce's summation order (jnp lowering exactly; Pallas
    kernel to chunking-order ulps — DESIGN.md §12)."""
    interp, kern = _resolve_lowering(path, interpret, use_kernel)
    w = weights.astype(jnp.float32)
    acc3 = None
    if acc is not None:
        # view the carried [d] plane in the kernels' [W, 128] layout
        acc3 = wire_view(acc.astype(jnp.float32)[None])[0]
    if kern:
        out = mixed_res_dequant_reduce(wire.signs, wire.hi, wire.codes,
                                       wire.head, w, b, acc=acc3,
                                       interpret=interp)
    else:
        out = _ref.mixed_res_dequant_reduce_ref(
            wire.signs, wire.hi, wire.codes, wire.head, w, b, acc=acc3)
    _tap_wire("wire.decode", int(wire.head.shape[0]), d * 4, wire)
    return out.reshape(-1)[:d]


def mixed_res_wire_aggregate(flat: jnp.ndarray, weights: jnp.ndarray,
                             lambda_: float, b: int, *,
                             interpret: bool | None = None,
                             use_kernel: bool | None = None,
                             path: WirePath | None = None):
    """The whole quantize-to-wire aggregation of the paper's scheme:
    encode U stacked deltas (two streaming passes) and reduce
    ``sum_g w_g * deq(wire_g)`` from the packed buffers.

    Returns ``(agg [d], bits [U], aux)`` where ``bits`` replays the
    reference accounting ``d (b s + 1 - s) + 32`` exactly (``dbar`` is
    an exact integer count) and ``aux`` mirrors
    ``mixed_resolution_quantize``'s aux dict.  The dense per-user
    reconstructions are never materialized."""
    U, d = flat.shape
    wire = mixed_res_encode(flat, lambda_, b, interpret=interpret,
                            use_kernel=use_kernel, path=path)
    agg = mixed_res_wire_reduce(wire, weights, b, d,
                                interpret=interpret,
                                use_kernel=use_kernel, path=path)
    inf = wire.head[:, H_INF]
    dw_q = wire.head[:, H_DWQ]
    dbar = wire.head[:, H_DBAR]
    s = dbar / d
    bits = d * (b * s + 1.0 - s) + 32.0
    bits = jnp.where(inf > 0, bits, float(d) + 32.0)
    aux = {"s": s, "dbar": dbar.astype(jnp.int32), "r": inf - dw_q,
           "dw_q": dw_q, "inf": inf}
    return agg, bits, aux


def segmented_wire_aggregate(flat: jnp.ndarray, weights: jnp.ndarray,
                             segments, *,
                             interpret: bool | None = None,
                             use_kernel: bool | None = None,
                             path: WirePath | None = None):
    """Per-layer-budget wire aggregation (DESIGN.md §13): one
    :func:`mixed_res_wire_aggregate` per contiguous budget segment,
    each with its own ``(lambda_, b)``, concatenated back into the full
    [d] aggregate.

    ``segments``: an ordered iterable of objects with ``start``,
    ``size``, ``lambda_`` and ``b`` attributes tiling [0, d)
    contiguously (``repro.core.quantize.Segment``; duck-typed so this
    module stays import-independent of core.quantize — the contiguity
    check is structural).  Returns ``(agg [d], bits [U], aux)`` where
    ``bits`` is the EXACT sum of the per-segment payloads (one 32-bit
    header per segment) and ``aux["segment_bits"]`` [U, n_seg] is the
    per-segment breakdown that sum is taken over.
    """
    U, d = flat.shape
    segments = tuple(segments)
    offset = 0
    for seg in segments:
        if seg.start != offset or seg.size <= 0:
            raise ValueError(
                f"segments must tile the flat vector contiguously: "
                f"segment {seg} at expected offset {offset}")
        offset += seg.size
    if offset != d:
        raise ValueError(
            f"segments cover {offset} entries but the flat vector has {d}")
    aggs, seg_bits, dbar = [], [], None
    for seg in segments:
        agg_s, bits_s, aux_s = mixed_res_wire_aggregate(
            flat[:, seg.start:seg.start + seg.size], weights,
            seg.lambda_, seg.b, interpret=interpret,
            use_kernel=use_kernel, path=path)
        aggs.append(agg_s)
        seg_bits.append(bits_s)
        db = aux_s["dbar"]
        dbar = db if dbar is None else dbar + db
    agg = jnp.concatenate(aggs)
    segment_bits = jnp.stack(seg_bits, axis=1)           # [U, n_seg]
    bits = jnp.sum(segment_bits, axis=1)
    aux = {"s": dbar.astype(jnp.float32) / float(d),
           "dbar": dbar.astype(jnp.int32),
           "segment_bits": segment_bits}
    return agg, bits, aux


@functools.partial(jax.jit, static_argnames=("interpret", "kv_block"))
def flash_decode_op(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    length: jnp.ndarray, kv_block: int = 512,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Single-token GQA decode attention.

    q: [B, H, D]; k/v: [B, S, Hkv, D(v)]; length: scalar int32.
    Returns [B, H, Dv]."""
    interp = _default_interpret() if interpret is None else interpret
    B, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    kt = k.transpose(0, 2, 1, 3)     # [B, Hkv, S, D]
    vt = v.transpose(0, 2, 1, 3)
    out = _flash_decode(qg, kt, vt, length, kv_block=kv_block,
                        interpret=interp)
    return out.reshape(B, H, -1)
