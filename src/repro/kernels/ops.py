"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) and
False on TPU, so the same call sites work in both environments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_decode import flash_decode as _flash_decode
from .quant_pack import sign_dequant_reduce as _sdr
from .quant_pack import signpack as _signpack


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def signpack_op(x: jnp.ndarray, interpret: bool | None = None
                ) -> jnp.ndarray:
    """Pack the sign plane of a flat f32 vector.

    x: [d] f32 with d % 128 == 0  ->  [d/32] uint32 (viewed flat)."""
    interp = _default_interpret() if interpret is None else interpret
    words = _signpack(x.reshape(-1, 128), interpret=interp)
    return words.reshape(-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sign_dequant_reduce_op(words: jnp.ndarray, scales: jnp.ndarray,
                           interpret: bool | None = None) -> jnp.ndarray:
    """words: [G, d/32] u32, scales: [G] -> [d] f32 weighted sign sum."""
    interp = _default_interpret() if interpret is None else interpret
    G = words.shape[0]
    out = _sdr(words.reshape(G, -1, 4), scales, interpret=interp)
    return out.reshape(-1)


def sign_pad_len(d: int) -> int:
    """Padded length for viewing a flat d-vector as signpack's [W, 128]
    rows with a valid block partition: W = ceil(d/128), padded up to a
    multiple of 256 rows once W exceeds one block."""
    rows = -(-d // 128)
    if rows > 256 and rows % 256:
        rows = -(-rows // 256) * 256
    return rows * 128


def packed_sign_weighted_sum(flat: jnp.ndarray, scales: jnp.ndarray,
                             interpret: bool | None = None) -> jnp.ndarray:
    """flat: [G, d] f32, scales: [G] f32 -> [d] f32 equal to
    ``sum_g scales_g * sign(flat_g)`` with sign(x) = +1 iff x > 0.

    Routes through the packed wire format: one signpack launch bit-packs
    all G sign planes ([G*W, 128] f32 -> uint32 words, the arrays a
    multi-peer aggregation actually moves), then sign_dequant_reduce
    fuses per-peer unpacking with the scale-weighted reduction.  Not
    jitted here — call sites trace it into their own jitted steps.
    """
    interp = _default_interpret() if interpret is None else interpret
    G, d = flat.shape
    d_pad = sign_pad_len(d)
    if d_pad != d:
        flat = jnp.pad(flat, ((0, 0), (0, d_pad - d)))
    rows = d_pad // 128
    # the G planes are stacked into one [G*rows, 128] launch, so the
    # block size must divide the per-plane row count (rows <= 256 after
    # sign_pad_len only when it IS the whole plane) — G*rows alone need
    # not be a multiple of the default 256-row block
    bm = rows if rows <= 256 else 256
    words = _signpack(flat.reshape(-1, 128), interpret=interp,
                      block_rows=bm)
    words = words.reshape(G, rows, 4)
    out = _sdr(words, scales.astype(jnp.float32), interpret=interp,
               block_rows=bm)
    return out.reshape(-1)[:d]


@functools.partial(jax.jit, static_argnames=("interpret", "kv_block"))
def flash_decode_op(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    length: jnp.ndarray, kv_block: int = 512,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Single-token GQA decode attention.

    q: [B, H, D]; k/v: [B, S, Hkv, D(v)]; length: scalar int32.
    Returns [B, H, Dv]."""
    interp = _default_interpret() if interpret is None else interpret
    B, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    kt = k.transpose(0, 2, 1, 3)     # [B, Hkv, S, D]
    vt = v.transpose(0, 2, 1, 3)
    out = _flash_decode(qg, kt, vt, length, kv_block=kv_block,
                        interpret=interp)
    return out.reshape(B, H, -1)
