"""Pure-jnp oracles for every Pallas kernel (the correctness ground
truth for the interpret-mode kernel tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def signpack_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: [W, 128] f32 -> words [W, 4] uint32 (bit j of word w,c is the
    sign of x[w, 32*c + j]; 1 <=> positive)."""
    W = x.shape[0]
    bits = (x > 0).astype(jnp.uint32).reshape(W, 4, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def sign_dequant_reduce_ref(words: jnp.ndarray, scales: jnp.ndarray
                            ) -> jnp.ndarray:
    """Fused multi-peer sign dequantization + weighted reduce.

    words: [G, W, 4] uint32 (per-peer packed sign planes);
    scales: [G] f32 (rho_g * dw_q_g / 2 per peer).
    Returns [W, 128] f32 = sum_g scales[g] * (+-1 bits of peer g).
    """
    G, W, _ = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)      # [G,W,4,32]
    signs = bits.astype(jnp.float32) * 2.0 - 1.0
    signs = signs.reshape(G, W, 128)
    return jnp.einsum("g,gwl->wl", scales, signs)


# ------------------------------------------------- mixed-res wire path
# jnp oracles for kernels/mixed_res.py, operating on the same [U, W,
# 128] padded views and emitting bit-identical packed planes.  Besides
# being the test ground truth, these compose (under one jit) into the
# streaming fallback pipeline benchmarks/quant_kernels.py measures on
# CPU, where interpret-mode Pallas is not a timing proxy.

def _head(h, lane):
    return h[:, lane].reshape(-1, 1, 1)


def mixed_res_reduce_ref(x: jnp.ndarray, lam: float, d_valid: int
                         ) -> jnp.ndarray:
    """x: [U, W, 128] f32 -> stats [U, 8] f32 (see mixed_res_reduce)."""
    from .mixed_res import H_DBAR, H_DWQ, H_INF, HEADER_LANES
    U, W, _ = x.shape
    absx = jnp.abs(x)
    inf = jnp.max(absx, axis=(1, 2))
    safe_inf = jnp.where(inf > 0, inf, 1.0)
    hi = (absx / safe_inf[:, None, None]) >= lam
    if d_valid != W * 128:
        valid = jnp.arange(W * 128).reshape(1, W, 128) < d_valid
        hi = hi & valid
    dwq_raw = jnp.min(jnp.where(hi, absx, jnp.inf), axis=(1, 2))
    dbar = jnp.sum(hi, axis=(1, 2)).astype(jnp.float32)
    stats = jnp.zeros((U, HEADER_LANES), jnp.float32)
    return stats.at[:, H_INF].set(inf).at[:, H_DWQ].set(dwq_raw) \
                .at[:, H_DBAR].set(dbar)


def mixed_res_emit_ref(x: jnp.ndarray, head: jnp.ndarray, b: int,
                       d_valid: int, *, anchored: bool = False):
    """x: [U, W, 128], head: [U, 8] -> (signs, hi, codes) packed u32
    planes (see mixed_res_emit)."""
    from .mixed_res import H_DWQ, H_INF, H_LAM, H_STEP, code_width
    U, W, _ = x.shape
    absx = jnp.abs(x)
    dw_q, step = _head(head, H_DWQ), _head(head, H_STEP)
    safe_step = jnp.where(step > 0, step, 1.0)
    if anchored:
        hi = absx >= dw_q
    else:
        inf = _head(head, H_INF)
        safe_inf = jnp.where(inf > 0, inf, 1.0)
        hi = (absx / safe_inf) >= _head(head, H_LAM)
    if d_valid != W * 128:
        hi = hi & (jnp.arange(W * 128).reshape(1, W, 128) < d_valid)
    # clamp mirrors the kernel: element-local cap at the grid top when
    # an approximate-top-k anchor header underestimates inf (otherwise
    # overflowing codes spill bits into neighboring packed slots)
    code = jnp.round((absx - dw_q) / safe_step)
    code = jnp.minimum(jnp.where(hi, code, 0.0),
                       float(2 ** b - 1)).astype(jnp.uint32)

    shifts32 = jnp.arange(32, dtype=jnp.uint32)
    pack1 = lambda bits: jnp.sum(
        bits.astype(jnp.uint32).reshape(U, W, 4, 32) << shifts32,
        axis=-1, dtype=jnp.uint32)
    bw = code_width(b)
    per = 32 // bw
    cshift = (jnp.arange(per, dtype=jnp.uint32) * bw)
    codes = jnp.sum(code.reshape(U, W, 128 * bw // 32, per) << cshift,
                    axis=-1, dtype=jnp.uint32)
    return pack1(x > 0), pack1(hi), codes


def mixed_res_dequant_reduce_ref(signs: jnp.ndarray, hi: jnp.ndarray,
                                 codes: jnp.ndarray, head: jnp.ndarray,
                                 weights: jnp.ndarray, b: int,
                                 acc: jnp.ndarray | None = None
                                 ) -> jnp.ndarray:
    """Packed wire planes of G users -> [W, 128] f32 weighted reduce
    (see mixed_res_dequant_reduce).

    ``acc`` ([W, 128] f32, optional) seeds the left fold with a carried
    accumulator so the reduction chains across cohort chunks:
    ``((acc + u_0) + u_1) + ...``.  Because the no-acc fold is the same
    left-to-right chain started at ``u_0``, folding a partition of the
    user axis chunk by chunk through ``acc`` reproduces the one-shot
    fold's float32 values exactly (only the sign of exact zeros can
    differ from the zeros init — invisible to ``==``; DESIGN.md §12)."""
    from .mixed_res import H_DWQ, H_STEP, code_width
    G, W, _ = signs.shape
    shifts32 = jnp.arange(32, dtype=jnp.uint32)
    unpack1 = lambda words: (
        (words[..., None] >> shifts32) & jnp.uint32(1)).reshape(W, 128)
    bw = code_width(b)
    per = 32 // bw
    cshift = jnp.arange(per, dtype=jnp.uint32) * bw
    cmask = jnp.uint32((1 << bw) - 1)

    # unrolled accumulation over the (static) user axis with the
    # weight folded into the grid scalars — one user's dense plane is
    # live at a time, and on CPU this lowers ~4x faster than a
    # G-contracted einsum (the kernel keeps the einsum — that shape
    # feeds the TPU MXU).  ``w*dwq + code*(w*step)`` differs from the
    # kernel's ``w * (dwq + code*step)`` by ~1 ulp per element; at
    # w = 1 (the roundtrip-parity case) both are exact.
    def one(g):
        sb = unpack1(signs[g]) > 0
        him = unpack1(hi[g]) > 0
        code = ((codes[g][..., None] >> cshift) & cmask).astype(
            jnp.float32).reshape(W, 128)
        wdq = weights[g] * head[g, H_DWQ]
        wst = weights[g] * head[g, H_STEP]
        mag = jnp.where(him, wdq + code * wst, wdq * 0.5)
        return jnp.where(sb, mag, -mag)              # mag >= 0

    out = one(0) if acc is None else acc + one(0)
    for g in range(1, G):
        out = out + one(g)
    return out


def xor_fold_words_ref(words: jnp.ndarray) -> jnp.ndarray:
    """[U, n] uint32 -> [U] uint32 xor fold — the wire-checksum oracle.

    XOR is associative and commutative, so the fold order is
    irrelevant: the checksum of a wire buffer is identical across the
    Pallas/interpret/jnp lowerings because the packed planes themselves
    are bit-exact across them (the kernel parity suite pins that).

    Folded as a zero-padded halving tree of vectorized xors rather
    than ``lax.reduce`` with a custom computation — the latter lowers
    to a scalar loop on the CPU backend, and the checksum has a <5%
    overhead budget on the wire path (benchmarks/resilience.py)."""
    w = words.astype(jnp.uint32)
    n = w.shape[1]
    if n == 0:
        return jnp.zeros((w.shape[0],), jnp.uint32)
    m = 1 << (n - 1).bit_length() if n > 1 else 1
    if m != n:
        w = jnp.pad(w, ((0, 0), (0, m - n)))
    while m > 1:
        m //= 2
        w = w[:, :m] ^ w[:, m:]
    return w[:, 0]


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     length: jnp.ndarray) -> jnp.ndarray:
    """Single-token decode attention oracle.

    q: [B, Hkv, G, D]; k: [B, Hkv, S, D]; v: [B, Hkv, S, Dv];
    length: scalar int32 — positions >= length are masked out.
    Returns [B, Hkv, G, Dv].
    """
    S = k.shape[2]
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(q.shape[-1])
    mask = jnp.arange(S) < length
    scores = jnp.where(mask[None, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgs,bhsv->bhgv", w,
                      v.astype(jnp.float32)).astype(q.dtype)
