"""Pure-jnp oracles for every Pallas kernel (the correctness ground
truth for the interpret-mode kernel tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def signpack_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x: [W, 128] f32 -> words [W, 4] uint32 (bit j of word w,c is the
    sign of x[w, 32*c + j]; 1 <=> positive)."""
    W = x.shape[0]
    bits = (x > 0).astype(jnp.uint32).reshape(W, 4, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def sign_dequant_reduce_ref(words: jnp.ndarray, scales: jnp.ndarray
                            ) -> jnp.ndarray:
    """Fused multi-peer sign dequantization + weighted reduce.

    words: [G, W, 4] uint32 (per-peer packed sign planes);
    scales: [G] f32 (rho_g * dw_q_g / 2 per peer).
    Returns [W, 128] f32 = sum_g scales[g] * (+-1 bits of peer g).
    """
    G, W, _ = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)      # [G,W,4,32]
    signs = bits.astype(jnp.float32) * 2.0 - 1.0
    signs = signs.reshape(G, W, 128)
    return jnp.einsum("g,gwl->wl", scales, signs)


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     length: jnp.ndarray) -> jnp.ndarray:
    """Single-token decode attention oracle.

    q: [B, Hkv, G, D]; k: [B, Hkv, S, D]; v: [B, Hkv, S, Dv];
    length: scalar int32 — positions >= length are masked out.
    Returns [B, Hkv, G, Dv].
    """
    S = k.shape[2]
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(q.shape[-1])
    mask = jnp.arange(S) < length
    scores = jnp.where(mask[None, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgs,bhsv->bhgv", w,
                      v.astype(jnp.float32)).astype(q.dtype)
