"""Pallas TPU kernels for the quantized-aggregation wire format.

These are the bandwidth-bound hot spots of the paper's technique at
datacenter scale: packing the 1-bit sign plane of a 10^8-element delta
shard, and the fused multi-peer dequantize+weighted-reduce after the
all-gather.  Both are elementwise streaming transforms -> VMEM-tiled
elementwise kernels with 128-lane last dims.

Layout convention: the flat f32 vector is viewed as [W, 128] (W = d /
128 rows); its packed sign plane is [W, 4] uint32 (4 words x 32 bits =
128 lanes).  The host-side reshape is free (layout-only).

TARGET is TPU (pl.pallas_call + BlockSpec); on this CPU-only container
the kernels run and are validated under interpret=True (see ops.py and
tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256            # rows of 128 lanes per VMEM tile


def _signpack_kernel(x_ref, out_ref):
    """x_ref: [bm, 128] f32 -> out_ref: [bm, 4] uint32."""
    x = x_ref[...]
    bits = (x > 0).astype(jnp.uint32).reshape(x.shape[0], 4, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    out_ref[...] = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def signpack(x: jnp.ndarray, *, interpret: bool = False,
             block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """x: [W, 128] f32 -> [W, 4] uint32 packed sign plane."""
    W = x.shape[0]
    bm = min(block_rows, W)
    assert W % bm == 0, (W, bm)
    return pl.pallas_call(
        _signpack_kernel,
        grid=(W // bm,),
        in_specs=[pl.BlockSpec((bm, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((W, 4), jnp.uint32),
        interpret=interpret,
    )(x)


def _sign_dequant_reduce_kernel(words_ref, scales_ref, out_ref):
    """words_ref: [G, bm, 4] u32; scales_ref: [G, 1] f32;
    out_ref: [bm, 128] f32 = sum_g scale_g * signs_g."""
    words = words_ref[...]
    G, bm, _ = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, None, :]
    bits = (words[..., None] >> shifts) & jnp.uint32(1)     # [G,bm,4,32]
    signs = bits.astype(jnp.float32) * 2.0 - 1.0
    signs = signs.reshape(G, bm, 128)
    scales = scales_ref[...].reshape(G)                     # [G]
    out_ref[...] = jnp.einsum("g,gwl->wl", scales, signs,
                              preferred_element_type=jnp.float32)


def sign_dequant_reduce(words: jnp.ndarray, scales: jnp.ndarray, *,
                        interpret: bool = False,
                        block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """words: [G, W, 4] u32, scales: [G] f32 -> [W, 128] f32.

    Fuses per-peer sign unpacking with the rho-weighted reduction over
    peers: the G x d intermediate float planes never hit HBM.
    """
    G, W, _ = words.shape
    bm = min(block_rows, W)
    assert W % bm == 0, (W, bm)
    return pl.pallas_call(
        _sign_dequant_reduce_kernel,
        grid=(W // bm,),
        in_specs=[pl.BlockSpec((G, bm, 4), lambda i: (0, i, 0)),
                  pl.BlockSpec((G, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bm, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((W, 128), jnp.float32),
        interpret=interpret,
    )(words, scales.reshape(G, 1))
