"""Flash-decode attention kernel: ONE query token against a long KV
cache with online softmax over KV blocks.

This is the serve-path hot spot for decode_32k / long_500k: memory-
bound streaming of the KV cache through VMEM with an O(1) running
(m, l, acc) state — the TPU adaptation of flash-decoding.  GQA is
handled by blocking over kv heads and carrying the whole query group
(G = H / Hkv) per kv head.

Grid: (B, Hkv, S/block) with the KV-block dimension innermost; the
running max / normalizer / accumulator live in VMEM scratch across the
KV-block iterations (initialized at block 0, emitted at the last
block).  Cache positions >= ``length`` (scalar) are masked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

KV_BLOCK = 512


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, out_ref,
                         m_ref, l_ref, acc_ref, *, kv_block: int):
    s = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)            # [bs, D]
    v = v_ref[0, 0].astype(jnp.float32)            # [bs, Dv]
    length = len_ref[0]

    scores = jnp.dot(q, k.T,
                     preferred_element_type=jnp.float32)    # [G, bs]
    scores = scores / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    pos = s * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                  scores.shape, 1)
    scores = jnp.where(pos < length, scores, -jnp.inf)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)          # [G, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(jnp.where(jnp.isfinite(scores),
                          scores - m_safe, -jnp.inf))        # [G, bs]
    scale = jnp.where(jnp.isfinite(m_prev),
                      jnp.exp(m_prev - m_safe), 0.0)         # [G, 1]
    l_new = l_prev * scale + jnp.sum(p, -1, keepdims=True)
    acc_new = acc_prev * scale + jnp.dot(
        p, v, preferred_element_type=jnp.float32)            # [G, Dv]
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(s == n_s - 1)
    def _emit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = out.astype(out_ref.dtype)


def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 length: jnp.ndarray, *, kv_block: int = KV_BLOCK,
                 interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hkv, G, D]; k: [B, Hkv, S, D]; v: [B, Hkv, S, Dv];
    length: scalar int32.  Returns [B, Hkv, G, Dv]."""
    B, Hkv, G, D = q.shape
    S = k.shape[2]
    Dv = v.shape[-1]
    bs = min(kv_block, S)
    assert S % bs == 0, (S, bs)
    grid = (B, Hkv, S // bs)
    kernel = functools.partial(_flash_decode_kernel, kv_block=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda b, h, s: (0,)),
                  pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
                  pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
                  pl.BlockSpec((1, 1, bs, Dv), lambda b, h, s: (b, h, s, 0))],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, Dv), jnp.float32)],
        interpret=interpret,
    )(length.reshape(1), q, k, v)
