"""repro.kernels — the Pallas wire-format kernel suite + its jnp
oracles, and the :class:`WirePath` spec that names which realization of
the packed exchange runs (owned here; consumed by both repro.sim and
repro.dist).

Heavy wrappers stay importable from :mod:`repro.kernels.ops`; this
package surface re-exports the spec plus the stable wire entrypoints so
sim/dist/config code never reaches into per-module internals.
"""
from .ops import (H_CHK, H_DBAR, H_DWQ, H_INF, H_LAM, H_STEP,
                  MixedResWire, mixed_res_encode,
                  mixed_res_encode_anchored, mixed_res_wire_aggregate,
                  mixed_res_wire_reduce, packed_sign_weighted_sum,
                  segmented_wire_aggregate, sign_pad_len,
                  stamp_checksum, verify_wire, wire_checksum, wire_view)
from .wire import (PACKED_DIM_LIMIT, WirePath, check_packed_dim,
                   from_aggregation, from_wire_path)

__all__ = [
    "H_CHK", "H_DBAR", "H_DWQ", "H_INF", "H_LAM", "H_STEP",
    "MixedResWire", "PACKED_DIM_LIMIT", "WirePath", "check_packed_dim",
    "from_aggregation", "from_wire_path",
    "mixed_res_encode", "mixed_res_encode_anchored",
    "mixed_res_wire_aggregate", "mixed_res_wire_reduce",
    "packed_sign_weighted_sum", "segmented_wire_aggregate",
    "sign_pad_len", "stamp_checksum", "verify_wire", "wire_checksum",
    "wire_view",
]
