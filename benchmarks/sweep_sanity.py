"""Sanity gate for the scheduled Monte-Carlo sweep artifact.

Asserts, across every cell of the metrics CSV written by
``benchmarks.mc_sweep``:

* all latency metrics are finite and non-negative, and every cell
  completed at least one round;
* power stayed physical: ``max_p <= 1`` (power-control coefficients,
  i.e. transmit power <= p_max) — populated by the batched phy driver;
* the replicated driver ran (``replicates`` column present, >= 2) and
  every latency confidence half-width (``<metric>_ci95``) is finite
  and non-negative — a NaN/inf CI means some replicate's trajectory
  diverged or the replicate axis silently collapsed.

    PYTHONPATH=src python -m benchmarks.sweep_sanity runs/mc_sweep.csv
"""
from __future__ import annotations

import csv
import math
import sys

LATENCY_FIELDS = ("total_latency_s", "mean_uplink_s", "p95_uplink_s")
CI_FIELDS = tuple(f + "_ci95" for f in LATENCY_FIELDS)


def check(path: str) -> int:
    with open(path, newline="") as f:
        rows = list(csv.DictReader(f))
    if not rows:
        print(f"FAIL: {path} has no sweep rows")
        return 1
    failures = []
    for row in rows:
        cell = f"{row['scenario']}/{row['quantizer']}/{row['power']}"
        if float(row["rounds"]) < 1:
            failures.append(f"{cell}: completed no rounds")
        for field in LATENCY_FIELDS:
            v = float(row[field])
            if not math.isfinite(v) or v < 0:
                failures.append(f"{cell}: {field}={v} not finite/>=0")
        if row.get("max_p", ""):
            v = float(row["max_p"])
            if not math.isfinite(v) or not 0.0 <= v <= 1.0:
                failures.append(
                    f"{cell}: max_p={v} outside [0, 1] (power > p_max)")
        else:
            failures.append(f"{cell}: max_p missing — sweep did not run "
                            "on the batched phy path")
        if row.get("replicates", ""):
            if float(row["replicates"]) < 2:
                failures.append(f"{cell}: replicates="
                                f"{row['replicates']} — no CI width "
                                "without >= 2 replicates")
            for field in CI_FIELDS:
                v = float(row.get(field, "nan"))
                if not math.isfinite(v) or v < 0:
                    failures.append(
                        f"{cell}: {field}={v} not finite/>=0")
        else:
            failures.append(f"{cell}: replicates column missing — "
                            "sweep did not run the replicated driver")
    if failures:
        print(f"FAIL ({len(failures)}):")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print(f"sweep sanity OK: {len(rows)} cells, finite latencies + CI "
          "widths, power <= p_max")
    return 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1] if len(sys.argv) > 1
                   else "runs/mc_sweep.csv"))
