"""Scheduled Monte-Carlo sweep — the weekly CI job's entry point.

Runs the REPLICATED batched driver (R Monte-Carlo replicates per cell
on the vmapped replicate axis — one jitted train call per quantizer
and one power solve per power spec per round regardless of R) on
2 scenarios x 2 quantizers x 2 power schemes and writes the metrics
CSV — now with across-replicate mean + ``<metric>_ci95`` confidence
columns — that the workflow uploads as an artifact and feeds to
``benchmarks.sweep_sanity`` (which also gates on CI-width finiteness):

    PYTHONPATH=src python -m benchmarks.mc_sweep runs/mc_sweep.csv

The sweep runs under an obs session (repro.obs): next to the CSV it
writes ``<base>_trace.jsonl`` (the raw event stream) and
``<base>_phases.txt`` (the rendered phase-time breakdown + per-round
table), both uploaded by the weekly workflow.
"""
from __future__ import annotations

import os
import sys

from repro import obs
from repro.obs.report import load_events, render_report
from repro.sim import run_grid_batched

SCENARIOS = ["monte-carlo-channel", "churn-0.7"]
QUANTIZERS = {"mixed": ("mixed-resolution", {"lambda_": 0.2, "b": 10}),
              "classic": ("classic", {})}
POWERS = {"ours": "bisection-lp", "maxsum": "max-sum-rate"}
REPLICATES = 4


def main(out_csv: str = "runs/mc_sweep.csv") -> None:
    base = os.path.splitext(out_csv)[0]
    trace = base + "_trace.jsonl"
    with obs.session(jsonl=trace, memory=False):
        results = run_grid_batched(SCENARIOS, QUANTIZERS, POWERS,
                                   quick=True, out_csv=out_csv,
                                   replicates=REPLICATES)
    report = render_report(load_events(trace))
    with open(base + "_phases.txt", "w") as f:
        f.write(report + "\n")
    print(report)
    for r in results:
        row = r.row()
        print(f"{row['scenario']},{row['quantizer']},{row['power']}: "
              f"rounds={row['rounds']:.0f} "
              f"total_latency={row['total_latency_s']:.3f}s"
              f"±{row['total_latency_s_ci95']:.3f} "
              f"(R={row['replicates']:.0f}) max_p={row['max_p']:.4f}")
    print(f"wrote {len(results)} rows to {out_csv}, trace to {trace}")


if __name__ == "__main__":
    main(*sys.argv[1:])
