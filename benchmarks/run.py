"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Quick mode (default)
uses reduced K/T so the whole harness finishes on this CPU container;
pass --full for paper-scale settings.  The roofline/dry-run tables are
produced by launch/roofline.py from the dry-run sweep, not here.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,table2,table3,overhead,"
                         "sim_engine")
    args = ap.parse_args()
    quick = not args.full

    from . import fig2_convergence, overhead, sim_engine, \
        table2_accuracy, table3_latency
    benches = {
        "overhead": lambda: overhead.run(quick=quick),
        "fig2": lambda: fig2_convergence.run(T=40 if quick else 100,
                                             quick=quick),
        "table2": lambda: table2_accuracy.run(quick=quick),
        "table3": lambda: table3_latency.run(quick=quick),
        "sim_engine": lambda: sim_engine.run(quick=quick),
    }
    selected = list(benches) if args.only is None \
        else args.only.split(",")

    print("name,us_per_call,derived")
    failed = False
    for name in selected:
        try:
            for line in benches[name]():
                print(line, flush=True)
        except Exception:
            failed = True
            traceback.print_exc()
            print(f"{name},nan,ERROR", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
