"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Quick mode (default)
uses reduced K/T so the whole harness finishes on this CPU container;
pass --full for paper-scale settings.  The roofline/dry-run tables are
produced by launch/roofline.py from the dry-run sweep, not here.

``--json out.json`` additionally writes structured records
``{name, us_per_call, derived, status}`` — one per CSV row, plus one
``status: "error"`` record (with the traceback) per bench group that
crashed, so the CI regression gate (benchmarks/check_regression.py)
can distinguish "slow" from "crashed".  In JSON mode the exit code is
0 even when a bench group fails: the per-bench statuses are the
contract and the gate enforces them; without --json a failure still
exits 1 (and prints the legacy ``name,nan,ERROR`` row) for direct
shell use.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def _env_meta() -> dict:
    """Environment stamp for emitted JSON: which jax/backend produced
    the numbers (regression diffs across environments are expected, and
    the gate needs to see that in the artifact, not guess)."""
    import platform

    meta = {"python": platform.python_version()}
    try:
        import jax
        meta.update(jax_version=jax.__version__,
                    backend=jax.default_backend(),
                    device_count=jax.device_count(),
                    x64=bool(jax.config.jax_enable_x64))
    except Exception as e:          # stamp what we can, never crash
        meta["jax_error"] = repr(e)[:200]
    return meta


def _parse_row(line: str) -> dict:
    name, us, derived = line.split(",", 2)
    try:
        us_val = float(us)
    except ValueError:
        us_val = None
    return {"name": name, "us_per_call": us_val, "derived": derived,
            "status": "ok"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,table2,table3,overhead,"
                         "sim_engine,phy_solvers,mc_replicates,"
                         "quant_kernels,async_rounds,cohort_scale,"
                         "layer_budget,resilience")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write structured per-bench records to OUT")
    args = ap.parse_args()
    quick = not args.full

    from . import async_rounds, cohort_scale, fig2_convergence, \
        layer_budget, mc_replicates, overhead, phy_solvers, \
        quant_kernels, resilience, sim_engine, table2_accuracy, \
        table3_latency
    benches = {
        "overhead": lambda: overhead.run(quick=quick),
        "fig2": lambda: fig2_convergence.run(T=40 if quick else 100,
                                             quick=quick),
        "table2": lambda: table2_accuracy.run(quick=quick),
        "table3": lambda: table3_latency.run(quick=quick),
        "sim_engine": lambda: sim_engine.run(quick=quick),
        "phy_solvers": lambda: phy_solvers.run(quick=quick),
        "mc_replicates": lambda: mc_replicates.run(quick=quick),
        "quant_kernels": lambda: quant_kernels.run(quick=quick),
        "async_rounds": lambda: async_rounds.run(quick=quick),
        "cohort_scale": lambda: cohort_scale.run(quick=quick),
        "layer_budget": lambda: layer_budget.run(quick=quick),
        "resilience": lambda: resilience.run(quick=quick),
    }
    selected = list(benches) if args.only is None \
        else args.only.split(",")

    print("name,us_per_call,derived")
    records = []
    failed = False
    for name in selected:
        t0 = time.time()
        # consume row-by-row so a generator bench crashing mid-group
        # still surfaces (and records) every row it produced first
        ok = True
        try:
            for line in benches[name]():
                print(line, flush=True)
                records.append(_parse_row(line))
        except Exception:
            ok = False
            failed = True
            traceback.print_exc()
            print(f"{name},nan,ERROR", flush=True)
            records.append({"name": name, "us_per_call": None,
                            "derived": "ERROR", "status": "error",
                            "error": traceback.format_exc()[-2000:]})
        if ok:
            records.append({"name": f"{name}/_wall", "us_per_call":
                            (time.time() - t0) * 1e6, "derived":
                            "group_wall_time", "status": "ok"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benches": records,
                       "meta": {"quick": quick, "groups": selected,
                                **_env_meta()}}, f, indent=2)
        return   # statuses recorded; the gate owns pass/fail
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
