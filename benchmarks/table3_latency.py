"""Table III — quantizers x power-control under a total latency budget.

For each quantizer (LAQ, Top-q, AQUILA, mixed-resolution) and each
power control (ours bisection+LP, Dinkelbach, max-sum-rate): run FL
over the CFmMIMO channel with a total latency budget and report T_max
(rounds completed) and test accuracy.  Paper: K=40, L=5, b=4,
lambda=0.4, budget 3s (quick mode scales these down).
"""
from __future__ import annotations

import csv
import os

import numpy as np

from repro.core.channel import CFmMIMOConfig, make_channel
from repro.core.power import (BisectionLPPowerControl,
                              DinkelbachPowerControl,
                              MaxSumRatePowerControl)
from repro.core.quantize import (AquilaQuantizer, LAQQuantizer,
                                 MixedResolutionQuantizer, TopQQuantizer)
from repro.fl import FLConfig, run_fl

from .common import Timer, csv_row, make_problem, split


def run(quick: bool = True, out="runs/bench"):
    os.makedirs(out, exist_ok=True)
    K = 8 if quick else 40
    T = 12 if quick else 60
    train, test, cfg = make_problem("cifar10-syn",
                                    n_train=2000 if quick else 8000)
    shards = split(train, K, iid=False)
    chan = make_channel(CFmMIMOConfig(K=K), seed=0)

    # calibrate the budget so the best scheme can do ~T rounds and the
    # worst is clearly capped (the paper uses an absolute 3 s budget)
    lam, b = 0.4, 4
    s_ref = 0.01
    quantizers = {
        "mixed-resolution": lambda: MixedResolutionQuantizer(lambda_=lam,
                                                             b=b),
        "top-q": lambda: TopQQuantizer(q=max(s_ref, 0.005)),
        "laq": lambda: LAQQuantizer(b=b, xi=0.5),
        "aquila": lambda: AquilaQuantizer(b_min=2, b_max=8, tol=0.05),
    }
    powers = {
        "ours-bisection-lp": BisectionLPPowerControl(),
        "dinkelbach": DinkelbachPowerControl(outer=4, inner=15),
        "max-sum-rate": MaxSumRatePowerControl(iters=20, restarts=0),
    }

    # budget: time for ~2/3 T rounds of classic-ish payload under our PC
    probe = run_fl(train, test, shards, cfg, quantizers["laq"](),
                   powers["ours-bisection-lp"], chan,
                   FLConfig(L=5, T=3, batch_size=32, alpha=0.01,
                            eval_every=3))
    per_round = probe.logs[-1].cum_latency_s / 3
    budget = per_round * T * 0.6

    lines, rows = [], []
    for qname, qf in quantizers.items():
        for pname, pc in powers.items():
            fl = FLConfig(L=5, T=T, batch_size=32, alpha=0.01,
                          eval_every=4, latency_budget_s=budget)
            with Timer() as t:
                res = run_fl(train, test, shards, cfg, qf(), pc, chan, fl)
            accs = [l.test_acc for l in res.logs if l.test_acc is not None]
            acc = max(accs) if accs else float("nan")
            rows.append([qname, pname, res.rounds_completed, acc,
                         res.mean_bits()])
            lines.append(csv_row(
                f"table3/{qname}/{pname}", t.seconds * 1e6,
                f"Tmax={res.rounds_completed};acc={acc:.3f};"
                f"bits={res.mean_bits():.2e}"))
    with open(os.path.join(out, "table3.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["quantizer", "power_control", "T_max", "best_acc",
                    "mean_bits"])
        w.writerows(rows)
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
