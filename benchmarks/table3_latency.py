"""Table III — quantizers x power-control under a total latency budget.

For each quantizer (LAQ, Top-q, AQUILA, mixed-resolution) and each
power control (ours bisection+LP, Dinkelbach, max-sum-rate): run FL
over the CFmMIMO channel with a total latency budget and report T_max
(rounds completed) and test accuracy.  Paper: K=40, L=5, b=4,
lambda=0.4, budget 3s (quick mode scales these down).

Runs as one quantizer x power grid on the repro.sim sweep runner
(vectorized engine, fused mode).
"""
from __future__ import annotations

import csv
import dataclasses
import os

from repro.core.power import (BisectionLPPowerControl,
                              DinkelbachPowerControl,
                              MaxSumRatePowerControl)
from repro.core.quantize import LAQQuantizer
from repro.sim import get_scenario, run_cell

from .common import Timer, csv_row


def run(quick: bool = True, out="runs/bench"):
    os.makedirs(out, exist_ok=True)
    K = 6 if quick else 40
    T = 8 if quick else 60
    scn = dataclasses.replace(
        get_scenario("paper-table3"), K=K, T=T, L=3 if quick else 5,
        n_train=1200 if quick else 8000,
        n_test=300 if quick else 1600, batch_size=32, lr=0.01,
        eval_every=4)   # budget-capped runs still get evaluated

    lam, b = 0.4, 4
    s_ref = 0.01
    quantizers = {
        "mixed-resolution": ("mixed-resolution",
                             {"lambda_": lam, "b": b}),
        "top-q": ("top-q", {"q": max(s_ref, 0.005)}),
        "laq": ("laq", {"b": b, "xi": 0.5}),
        "aquila": ("aquila", {"b_min": 2, "b_max": 8, "tol": 0.05}),
    }
    powers = {
        "ours-bisection-lp": BisectionLPPowerControl(),
        "dinkelbach": DinkelbachPowerControl(outer=4, inner=15),
        "max-sum-rate": MaxSumRatePowerControl(iters=20, restarts=0),
    }

    # budget: time for ~2/3 T rounds of classic-ish payload under our PC
    probe = run_cell(dataclasses.replace(scn, T=3),
                     LAQQuantizer(b=b, xi=0.5),
                     powers["ours-bisection-lp"], quick=False)
    per_round = probe.result.logs[-1].cum_latency_s / 3
    budget = per_round * T * 0.6

    lines, rows = [], []
    for qname, qspec in quantizers.items():
        for pname, pc in powers.items():
            with Timer() as t:
                res = run_cell(scn, qspec, pc, quick=False,
                               latency_budget_s=budget,
                               labels=(qname, pname))
            acc = res.summary["best_acc"]
            rows.append([qname, pname, res.result.rounds_completed, acc,
                         res.summary["mean_bits_per_user"]])
            lines.append(csv_row(
                f"table3/{qname}/{pname}", t.seconds * 1e6,
                f"Tmax={res.result.rounds_completed};acc={acc:.3f};"
                f"bits={res.summary['mean_bits_per_user']:.2e}"))
    with open(os.path.join(out, "table3.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["quantizer", "power_control", "T_max", "best_acc",
                    "mean_bits"])
        w.writerows(rows)
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
