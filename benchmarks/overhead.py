"""Overhead-reduction accounting (r-bar, §IV) + the beyond-paper
rate-aware bit allocation, plus wire-format microbenchmarks of the
pack/dequant reference path (the Pallas kernels' jnp oracle)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.power import (equalizing_target_latency,
                              rate_aware_fractions)
from repro.core.quantize import (MixedResolutionQuantizer, pack_signs,
                                 static_budget_roundtrip, wire_bits)
from repro.kernels.ops import sign_dequant_reduce_op, signpack_op

from .common import csv_row


def _time(fn, *args, n=10):
    """Best-of-n wall time (us): the minimum is the stable statistic
    for a microbench on a shared machine — the CI regression gate
    compares these numbers across runs."""
    fn(*args)  # compile
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(quick: bool = True, out="runs/bench"):
    lines = []
    rng = np.random.default_rng(0)

    # r-bar for the paper's formula at the Table II/III operating points
    for lam, b, s_meas in [(0.05, 10, 0.01), (0.2, 10, 0.009),
                           (0.4, 4, 0.00044)]:
        rbar = 100 - 100 / 32 - 100 * s_meas * (b - 1) / 32
        lines.append(csv_row(f"overhead/rbar_lam{lam}", 0.0,
                             f"rbar={rbar:.1f}%_vs_32bit"))

    # static wire format vs fp32 all-reduce bytes
    d, k, b = 2 ** 24, 2 ** 24 // 100, 4
    ratio = wire_bits(d, k, b) / (32 * d)
    lines.append(csv_row("overhead/static_wire_ratio", 0.0,
                         f"bytes_ratio={ratio:.4f}"))

    # rate-aware bit allocation (beyond-paper)
    rates = rng.uniform(0.5e6, 8e6, 16)
    ell = equalizing_target_latency(rates, d=10 ** 6, b=8, s_floor=0.005)
    s = rate_aware_fractions(rates, 10 ** 6, 8, ell, s_min=0.005)
    lines.append(csv_row("overhead/rate_aware_alloc", 0.0,
                         f"latency={ell:.3f}s;s_spread={s.max()/s.min():.1f}x"))

    # pack/dequant micro (jnp reference path == kernel oracle)
    dd = 2 ** 18 if quick else 2 ** 22
    x = jnp.asarray(rng.standard_normal(dd), jnp.float32)
    us = _time(signpack_op, x)
    lines.append(csv_row("kernels/signpack_interpret+ref", us,
                         f"d={dd};GBps={dd * 4 / us / 1e3:.2f}"))
    words = signpack_op(x)
    scales = jnp.asarray(rng.uniform(0.1, 1, 8), jnp.float32)
    w8 = jnp.broadcast_to(words[None], (8,) + words.shape)
    us = _time(sign_dequant_reduce_op, w8, scales)
    lines.append(csv_row("kernels/sign_dequant_reduce", us,
                         f"G=8;d={dd}"))

    # quantize roundtrip throughput (simulation layer)
    q = MixedResolutionQuantizer(lambda_=0.2, b=10)
    f = jax.jit(lambda v: q(v)[0].recon)
    us = _time(f, x)
    lines.append(csv_row("quantize/mixed_res_roundtrip", us, f"d={dd}"))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
