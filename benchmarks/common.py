"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.configs.paper_cnn import CIFAR10, CIFAR100, FASHION
from repro.data import (make_image_classification, partition_dirichlet,
                        partition_iid)

DATASETS = {
    "cifar10-syn": (CIFAR10, 10),
    "cifar100-syn": (CIFAR100, 100),
    "fashion-syn": (FASHION, 10),
}


def make_problem(name: str, n_train: int = 4000, n_test: int = 800,
                 seed: int = 0):
    """(train, test, cnn_cfg) for one of the paper's three datasets
    (synthetic stand-ins — offline container, see DESIGN.md)."""
    cnn_cfg, n_classes = DATASETS[name]
    full = make_image_classification(
        n_samples=n_train + n_test, hw=cnn_cfg.input_hw,
        channels=cnn_cfg.channels, n_classes=n_classes, seed=seed)
    train = dataclasses.replace(full, x=full.x[:n_train],
                                y=full.y[:n_train])
    test = dataclasses.replace(full, x=full.x[n_train:], y=full.y[n_train:])
    return train, test, cnn_cfg


def split(train, K: int, iid: bool, seed: int = 0):
    if iid:
        return partition_iid(train, K, seed)
    return partition_dirichlet(train, K, alpha=0.3, seed=seed)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
