"""Batched phy solvers vs per-realization numpy solve wall-time.

The repro.phy acceptance bar: at batch >= 64 the jitted batched solve
must be >= 10x faster than looping the numpy reference controller over
the realizations (the control-plane bottleneck run_grid paid before
the batched driver).  Compile time is excluded (one warm call), the
batched timing is min-of-3, and the numpy loop is timed once (it is
the slow side by an order of magnitude).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.channel import CFmMIMOConfig, make_channel
from repro.core.power import (BisectionLPPowerControl,
                              DinkelbachPowerControl,
                              MaxSumRatePowerControl)
from repro.phy import (bisection_solve, bundle_from_realizations,
                       dinkelbach_solve, maxsum_solve)

from .common import csv_row


def _time_batched(fn, reps: int = 3) -> float:
    fn()                                   # warm / compile
    best = np.inf
    for _ in range(reps):
        t0 = time.time()
        sol = fn()
        _ = np.asarray(sol.latencies)      # block on device results
        best = min(best, time.time() - t0)
    return best


def _bench_solver(name: str, batched_fn, host_ctrl, chans, bits):
    t_batched = _time_batched(batched_fn)
    t0 = time.time()
    for i, c in enumerate(chans):
        host_ctrl.solve(c, bits[i])
    t_host = time.time() - t0
    speedup = t_host / t_batched
    B = len(chans)
    return csv_row(
        f"phy_solvers/{name}_b{B}", t_batched * 1e6,
        f"np_ms={t_host * 1e3:.1f};jax_ms={t_batched * 1e3:.1f};"
        f"speedup={speedup:.1f}x;B={B};K={chans[0].cfg.K}")


def run(quick: bool = True):
    B = 64 if quick else 256
    cfg = CFmMIMOConfig(K=20, M=16)
    chans = [make_channel(cfg, seed=s) for s in range(B)]
    cb = bundle_from_realizations(chans)
    rng = np.random.default_rng(0)
    bits = rng.uniform(1e5, 2e6, (B, cfg.K))

    lines = [_bench_solver(
        "bisection", lambda: bisection_solve(cb, bits),
        BisectionLPPowerControl(), chans, bits)]
    # reduced iteration counts keep the numpy side's FD loops within a
    # CI budget; both sides use the same counts
    lines.append(_bench_solver(
        "dinkelbach",
        lambda: dinkelbach_solve(cb, bits, outer=4, inner=10),
        DinkelbachPowerControl(outer=4, inner=10), chans, bits))
    lines.append(_bench_solver(
        "maxsum",
        lambda: maxsum_solve(cb, bits, iters=40, restarts=1),
        MaxSumRatePowerControl(iters=40, restarts=1), chans, bits))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
