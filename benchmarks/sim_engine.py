"""Vectorized engine vs sequential loop — per-round wall clock.

Times one FL round of the legacy sequential loop (one jit dispatch per
user + eager per-user quantization; repro.fl.run_fl_sequential) against
the repro.sim vectorized engine in its fused production mode, at K=20
and K=40.

Two workload points:
* ``dispatch`` — small per-user local step (L=1, b=2, tiny CNN): the
  regime the engine targets, where the sequential loop's per-user
  dispatch + eager-op overhead dominates; the engine collapses it into
  one jit step per round (>= 5x at K=20 is the acceptance bar; this
  box measures ~15-25x).
* ``paperlike`` — the hw=16 CNN at L=5, b=32: per-user compute (conv
  grads) dominates on CPU, so the win shrinks toward compute parity;
  reported for honesty.  On accelerators the vmap batching recovers
  the gap (local_batching="vmap").
"""
from __future__ import annotations

import dataclasses
import time

from repro.configs.paper_cnn import PaperCNNConfig
from repro.core.quantize import MixedResolutionQuantizer
from repro.data import make_image_classification, partition_iid
from repro.fl import FLConfig, run_fl_sequential
from repro.sim import EngineConfig, VectorizedFLEngine

from .common import csv_row

_DISPATCH_CNN = PaperCNNConfig(input_hw=8, n_classes=4, conv_filters=4,
                               dense_units=64)
_PAPERLIKE_CNN = PaperCNNConfig(input_hw=16, n_classes=4)


def _time_per_round(fn, T: int) -> float:
    fn()                                   # warm / compile
    t0 = time.time()
    fn()
    return (time.time() - t0) / T


def _bench_point(name: str, cnn_cfg: PaperCNNConfig, K: int, L: int,
                 b: int, T: int):
    n = max(1200, K * 60)
    full = make_image_classification(n_samples=n, hw=cnn_cfg.input_hw,
                                     n_classes=cnn_cfg.n_classes, seed=0)
    train = dataclasses.replace(full, x=full.x[:n - 200],
                                y=full.y[:n - 200])
    test = dataclasses.replace(full, x=full.x[n - 200:],
                               y=full.y[n - 200:])
    shards = partition_iid(train, K)
    fl = FLConfig(L=L, T=T, batch_size=b, alpha=0.02, eval_every=10_000,
                  seed=0)

    quant = MixedResolutionQuantizer(lambda_=0.2, b=10)
    engine = VectorizedFLEngine(train, test, shards, cnn_cfg, quant,
                                None, None, fl,
                                engine=EngineConfig(fused=True))
    t_eng = _time_per_round(lambda: engine.run(), T)
    t_seq = _time_per_round(
        lambda: run_fl_sequential(train, test, shards, cnn_cfg, quant,
                                  None, None, fl), T)
    speedup = t_seq / t_eng
    return csv_row(
        f"sim_engine/{name}", t_eng * 1e6,
        f"seq_ms={t_seq * 1e3:.1f};eng_ms={t_eng * 1e3:.1f};"
        f"speedup={speedup:.1f}x;K={K};L={L};b={b};d={engine.d}")


def run(quick: bool = True, out="runs/bench"):
    T = 6 if quick else 10
    lines = [
        _bench_point("dispatch-K20", _DISPATCH_CNN, 20, 1, 2, T),
        _bench_point("dispatch-K40", _DISPATCH_CNN, 40, 1, 2, T),
    ]
    # compute-bound reference point (scaled down in quick mode)
    if quick:
        lines.append(_bench_point("paperlike-K20", _PAPERLIKE_CNN,
                                  20, 2, 16, 3))
    else:
        lines.append(_bench_point("paperlike-K20", _PAPERLIKE_CNN,
                                  20, 5, 32, 3))
        lines.append(_bench_point("paperlike-K40", _PAPERLIKE_CNN,
                                  40, 5, 32, 3))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
