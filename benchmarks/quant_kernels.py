"""Fused mixed-resolution wire kernels vs the composite quantize+pack
path (DESIGN.md section 9).

Two comparisons, both at the paper-scale d = 262144 (quick) /
4194304 (--full), b = 8, lambda = 0.2:

* **encode** — the fused quantize-to-wire pipeline
  (``ops.mixed_res_encode`` at its CPU default lowering: the streaming
  jnp composition of the ref.py oracles under one jit; the Pallas
  kernels under interpret are timed for the record, not the gate)
  against the CURRENT composite at its shipped defaults: the
  ``mixed_res_roundtrip`` jit (dense recon materialized) followed by
  the separate packing stage (``signpack_op`` + jnp ``pack_codes``).
* **dequant-reduce** — ``ops.mixed_res_wire_reduce`` (one fused
  decode+weighted-reduce) against the per-peer jnp unpack loop at
  G = 8 peers.

The CI regression gate (BENCH_baseline.json) pins both fused rows; the
encode speedup is additionally asserted >= 1.5x here so the bench
fails loudly if the fused path ever loses its reason to exist.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import (pack_codes, pack_signs, unpack_codes,
                                 unpack_signs)
from repro.core.quantize.mixed_resolution import mixed_resolution_quantize
from repro.kernels import ops
from repro.kernels.mixed_res import (BLOCK_ROWS, H_DWQ, H_STEP,
                                     code_width, code_words_per_row)

from .common import csv_row

LAM, B, G = 0.2, 8, 8
MIN_ENCODE_SPEEDUP = 1.5


def _time(fn, *args, n=10):
    fn(*args)
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _assert_tpu_shaped(d: int) -> str:
    """The tiling contract of quant_pack.py, checked at bench time:
    128-lane last dims and VMEM-bounded blocks."""
    x3 = ops.wire_view(jnp.zeros((1, d), jnp.float32))
    _, W, lanes = x3.shape
    assert lanes == 128, lanes
    bm = min(BLOCK_ROWS, W)
    assert W % bm == 0, (W, bm)
    tile_kb = (bm * 128 * 4 + 2 * bm * 16 + bm * 4 *
               code_words_per_row(B) + 32) // 1024
    assert tile_kb * 1024 < 16 * 2 ** 20, tile_kb  # fits VMEM
    return f"bm={bm};lanes=128;tile_kb={tile_kb};bw={code_width(B)}"


def _composite_fns():
    """Today's two-stage path: quantize (dense recon + bits) jit, then
    the separate packing stage at its shipped defaults (Pallas
    signpack under interpret on CPU + jnp code packing)."""
    f_quant = jax.jit(lambda v: mixed_resolution_quantize(v, LAM, B))

    def pack_stage(v, dw_q, r, inf):
        absx = jnp.abs(v)
        step = r / (2 ** B - 1)
        safe = jnp.where(step > 0, step, 1.0)
        hi = (absx / jnp.where(inf > 0, inf, 1.0)) >= LAM
        code = jnp.where(hi, jnp.round((absx - dw_q) / safe), 0.0)
        return (pack_codes(hi.astype(jnp.uint32), 1),
                pack_codes(code.astype(jnp.uint32), B))

    f_pack = jax.jit(pack_stage)

    def composite(v):
        res = f_quant(v)
        signs = ops.signpack_op(v)              # current wire packing
        hiw, codes = f_pack(v, res.aux["dw_q"], res.aux["r"],
                            res.aux["inf"])
        return res.bits, signs, hiw, codes

    def composite_jnp(v):
        """Same stages with the sign plane also jnp-packed — the
        lowering-matched (no interpret overhead) comparison."""
        res = f_quant(v)
        signs = _jnp_signs(v)
        hiw, codes = f_pack(v, res.aux["dw_q"], res.aux["r"],
                            res.aux["inf"])
        return res.bits, signs, hiw, codes

    _jnp_signs = jax.jit(pack_signs)
    return composite, composite_jnp


def _per_peer_dequant(wire, weights, d):
    """The decode a per-peer jnp loop pays today: G separate unpacks
    plus a dense weighted accumulation."""
    out = jnp.zeros(d, jnp.float32)
    for g in range(G):
        signs = unpack_signs(wire.signs[g].reshape(-1), d)
        him = unpack_codes(wire.hi[g].reshape(-1), 1, d) > 0
        code = unpack_codes(wire.codes[g].reshape(-1), code_width(B),
                            d).astype(jnp.float32)
        mag = jnp.where(him, wire.head[g, H_DWQ]
                        + code * wire.head[g, H_STEP],
                        wire.head[g, H_DWQ] * 0.5)
        out = out + weights[g] * signs * mag
    return out


def run(quick: bool = True):
    lines = []
    d = 2 ** 18 if quick else 2 ** 22
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(d), jnp.float32)

    lines.append(csv_row("kernels/mixed_res_tiling", 0.0,
                         _assert_tpu_shaped(d)))

    # ------------------------------------------------------- encode
    composite, composite_jnp = _composite_fns()
    us_comp = _time(composite, x)
    us_comp_jnp = _time(composite_jnp, x)
    f_fused = jax.jit(lambda v: ops.mixed_res_encode(v[None], LAM, B))
    us_fused = _time(f_fused, x)
    speedup = us_comp / us_fused
    lines.append(csv_row(
        "kernels/mixed_res_encode_fused", us_fused,
        f"d={d};composite_us={us_comp:.0f};speedup={speedup:.2f}x;"
        f"jnp_repack_composite_us={us_comp_jnp:.0f};"
        f"vs_jnp_repack={us_comp_jnp / us_fused:.2f}x"))
    assert speedup >= MIN_ENCODE_SPEEDUP, (
        f"fused encode only {speedup:.2f}x vs the composite "
        f"(need >= {MIN_ENCODE_SPEEDUP}x)")

    # Pallas lowering under interpret — recorded (slow on CPU by
    # construction; the TPU-lowering proxy is the tiling assert above)
    f_interp = jax.jit(lambda v: ops.mixed_res_encode(
        v[None], LAM, B, interpret=True, use_kernel=True))
    lines.append(csv_row("kernels/mixed_res_encode_interpret",
                         _time(f_interp, x, n=3), f"d={d}"))

    # ------------------------------------------------ dequant+reduce
    xs = jnp.asarray(rng.standard_normal((G, d)), jnp.float32)
    wire = jax.jit(lambda v: ops.mixed_res_encode(v, LAM, B))(xs)
    weights = jnp.asarray(rng.uniform(0.1, 1.0, G), jnp.float32)
    f_dq = jax.jit(lambda w_, s: ops.mixed_res_wire_reduce(
        ops.MixedResWire(*w_), s, B, d))
    us_dq = _time(f_dq, tuple(wire), weights)
    f_pp = jax.jit(lambda w_, s: _per_peer_dequant(
        ops.MixedResWire(*w_), s, d))
    us_pp = _time(f_pp, tuple(wire), weights)
    lines.append(csv_row(
        "kernels/mixed_res_dequant_reduce_fused", us_dq,
        f"G={G};d={d};per_peer_us={us_pp:.0f};"
        f"speedup={us_pp / us_dq:.2f}x"))

    # simulated-buffer weight: the dense-slot wire buffers the kernels
    # move (sign + hi + bw-bit code planes).  The ACCOUNTED payload is
    # the paper's d(bs + 1 - s) + 32 — ~0.04x f32 at the measured s —
    # see DESIGN.md section 9 on why the simulation buffer is denser.
    words = (-(-d // 32)) * 2 + d * code_width(B) // 32 + 8
    lines.append(csv_row(
        "kernels/mixed_res_wire_bytes", 0.0,
        f"sim_buffer_ratio={words * 4 / (4 * d):.4f}_vs_f32"))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
