"""Tier-1 observability trace — the CI ``obs`` job's entry point.

Runs one quick batched-driver cell (paper-table3, mixed-resolution
quantizer, bisection-LP power control) under an obs session and writes
the JSONL event stream CI uploads as the ``tier1-obs-trace`` artifact,
then prints the rendered report (per-round phase timings, straggler
percentiles, payload bits, solver iteration counts) so the job log is
readable without downloading anything:

    PYTHONPATH=src python -m benchmarks.obs_trace runs/tier1_trace.jsonl
"""
from __future__ import annotations

import sys

from repro import obs
from repro.obs.report import load_events, render_report
from repro.sim import run_grid_batched

SCENARIOS = ["paper-table3"]
QUANTIZERS = {"mixed": ("mixed-resolution", {"lambda_": 0.2, "b": 10})}
POWERS = {"ours": "bisection-lp"}


def main(trace: str = "runs/tier1_trace.jsonl") -> None:
    with obs.session(jsonl=trace, memory=False):
        results = run_grid_batched(SCENARIOS, QUANTIZERS, POWERS,
                                   quick=True)
    print(render_report(load_events(trace)))
    print(f"\n{len(results)} cells; trace written to {trace}")


if __name__ == "__main__":
    main(*sys.argv[1:])
