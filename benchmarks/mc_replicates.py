"""Vmapped replicate axis vs host-looped Monte-Carlo replicates.

The weekly MC job's regime: R independent trajectories of one
(scenario, quantizer, power) cell.  The replicated driver
(``run_grid_batched(replicates=R)``) trains all R in one jitted
dispatch per round and solves all R uplink problems in one device
call; the host-looped baseline is what the job paid before — R
independent unreplicated runs with per-replicate seeds.

Two rows, measuring different things honestly:

* ``endtoend`` — one full job invocation per side, INCLUDING problem
  build + jit trace/compile (run_grid_batched builds fresh engines
  per call, so every real job pays this).  The replicated side
  amortizes R problem builds + compiles into one — the dominant win
  for the weekly job on CPU (~2-3x here).
* ``steady`` — the difference between a T_HI-round and a T_LO-round
  run on each side; identical build/compile work cancels, leaving
  (T_HI - T_LO) rounds of pure per-round stepping.  On this 2-core
  CPU the per-replicate conv compute dominates and the batched
  dispatch win is ~1x; on accelerators the vmapped replicate axis is
  where this row earns its keep.
"""
from __future__ import annotations

import dataclasses
import time

from repro.sim import get_scenario, run_grid_batched

from .common import csv_row

QUANT = {"mixed": ("mixed-resolution", {"lambda_": 0.2, "b": 4})}
POWER = {"ours": "bisection-lp"}
T_LO = 2


def _scenario(T: int, seed: int = 0):
    return dataclasses.replace(
        get_scenario("monte-carlo-channel"), name="mc-replicates-bench",
        K=8, T=T, n_train=480, n_test=96, batch_size=8, L=1, seed=seed)


def _time(fn) -> float:
    t0 = time.time()
    fn()
    return time.time() - t0


def run(quick: bool = True):
    R = 8
    T_hi = 8 if quick else 20
    rounds = T_hi - T_LO

    def repl_at(T):
        return run_grid_batched([_scenario(T)], QUANT, POWER,
                                quick=False, replicates=R)

    def loop_at(T):
        # host-looped baseline: R unreplicated runs, per-replicate
        # seeds (channel + data geometry vary with the seed, as the
        # replicate axis varies them per trajectory)
        return [run_grid_batched([_scenario(T, seed=r)], QUANT, POWER,
                                 quick=False) for r in range(R)]

    # end-to-end job cost (build + compile + T_hi rounds), then the
    # short runs whose difference isolates steady-state stepping
    t_repl_hi = _time(lambda: repl_at(T_hi))
    t_loop_hi = _time(lambda: loop_at(T_hi))
    t_repl = t_repl_hi - _time(lambda: repl_at(T_LO))
    t_loop = t_loop_hi - _time(lambda: loop_at(T_LO))
    return [
        csv_row(f"mc_replicates/endtoend-R{R}", t_repl_hi * 1e6,
                f"loop_s={t_loop_hi:.2f};repl_s={t_repl_hi:.2f};"
                f"speedup={t_loop_hi / t_repl_hi:.1f}x;R={R};T={T_hi}"),
        csv_row(f"mc_replicates/steady-R{R}", t_repl / rounds * 1e6,
                f"loop_ms={t_loop * 1e3:.1f};repl_ms={t_repl * 1e3:.1f};"
                f"speedup={t_loop / t_repl:.1f}x;R={R};rounds={rounds}"),
    ]


if __name__ == "__main__":
    for line in run():
        print(line)
