"""Async round engine vs lockstep — wall-clock and latency-to-accuracy.

The regime async rounds target: K=20 users with HEAVY-TAILED upload
times (Zipf(1.3) shard sizes make payload bits — and therefore solved
upload latencies — heavy-tailed), where the lockstep engine charges
every round the slowest user's completion time while the async engine
(DESIGN.md §11) closes at the median pending completion and folds
stragglers into later rounds through the staleness buffer.

Two rows, measuring different things honestly:

* ``wall`` — host+device wall-clock of one full async job vs the
  lockstep job on the same scenario.  The async round costs one extra
  jitted dispatch (train/aggregate split), so this row gates the
  overhead of the async machinery, not a speedup — measured at or
  below 1x on this CPU.
* ``simlat`` — the metric async rounds exist for: SIMULATED uplink
  seconds per round (the event clock's round duration vs the lockstep
  straggler latency) and the final accuracy both sides reach in the
  same number of rounds.  Under max-sum-rate power the lockstep
  straggler is hostage to near-zero-rate users, so the uplink ratio
  is enormous (thousands of x) — that IS the finding, and the paper's
  min-max controller is the other way to buy it back.  The derived
  field prints both accuracies next to the latency win, so the
  accuracy cost of early-closing rounds is never hidden.
"""
from __future__ import annotations

import dataclasses
import time

from repro.sim import get_scenario, run_grid_batched

from .common import csv_row

QUANT = {"mixed": ("mixed-resolution", {"lambda_": 0.2, "b": 4})}
# max-sum-rate, deliberately: the paper's min-max controller EQUALIZES
# per-user latencies (no tail left to cut), while max-sum-rate leaves
# the rate distribution heavy-tailed — the regime async rounds target
POWER = {"maxsum": "max-sum-rate"}
K = 20


def _scenarios(T: int):
    lockstep = dataclasses.replace(
        get_scenario("hetero-data"), name="async-bench-lockstep",
        K=K, T=T, n_train=1200, n_test=200, batch_size=8, L=1,
        partition="powerlaw")
    async_ = dataclasses.replace(
        lockstep, name="async-bench-async", async_mode=True,
        deadline_quantile=0.5, staleness_alpha=0.5, max_staleness=2)
    return lockstep, async_


def run(quick: bool = True):
    T = 6 if quick else 20
    lockstep, async_ = _scenarios(T)

    def job(scn):
        t0 = time.time()
        res = run_grid_batched([scn], QUANT, POWER, quick=False)[0]
        return time.time() - t0, res.summary

    t_lock, s_lock = job(lockstep)
    t_async, s_async = job(async_)

    # uplink_ratio is the event-clock win itself (lockstep straggler
    # vs async round duration); total simulated latency additionally
    # carries the per-round computation constant, which async does not
    # change, so both are printed
    up_lock, up_async = s_lock["mean_uplink_s"], s_async["mean_uplink_s"]
    return [
        csv_row(f"async_rounds/wall-K{K}", t_async * 1e6,
                f"lock_s={t_lock:.2f};async_s={t_async:.2f};"
                f"overhead={t_async / t_lock:.2f}x;T={T}"),
        csv_row(f"async_rounds/simlat-K{K}", 0.0,
                f"uplink_ratio={up_lock / up_async:.2f}x;"
                f"sim_lock_s={s_lock['total_latency_s']:.3f};"
                f"sim_async_s={s_async['total_latency_s']:.3f};"
                f"acc_lock={s_lock['final_acc']:.3f};"
                f"acc_async={s_async['final_acc']:.3f};"
                f"eff_part={s_async['effective_participation']:.2f};"
                f"staleness={s_async['mean_staleness']:.2f}"),
    ]


if __name__ == "__main__":
    for line in run():
        print(line)
