"""Per-layer budget overhead (DESIGN.md §13).

The segmented wire aggregate runs one mixed-res encode per budget
segment instead of one global pass.  This bench times the global
``mixed_res_wire_aggregate`` against ``segmented_wire_aggregate`` at
3 segments on the same ``[K, d]`` deltas — both under one jit, CPU
default lowering — plus the dense-plane ``segmented_quantize``.  The
gate pins the segmented rows so the per-segment loop never silently
regresses past linear cost in the segment count; the derived column
carries the segmented/global ratio for the record.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import LayerBudget, segmented_quantize
from repro.kernels.ops import (mixed_res_wire_aggregate,
                               segmented_wire_aggregate)

from .common import csv_row

LAM, B = 0.2, 10


def _time(fn, *args, n=10):
    fn(*args)
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(quick: bool = True):
    K = 8 if quick else 20
    d_mat = 65536 if quick else 1048576
    d_norm = 1024 if quick else 4096
    # a transformer-shaped toy tree: embed + norm + matmul groups
    tree = {"a_embed_tokens": jnp.zeros((d_mat // 256, 256)),
            "b_ln": jnp.zeros((d_norm,)),
            "c_w": jnp.zeros((d_mat // 256, 256))}
    lb = LayerBudget.by_group(embed=(0.4, 4), norm=(0.05, 12),
                              matmul=(LAM, B))
    segments = lb.segments_for(tree, LAM, B)
    d = sum(s.size for s in segments)
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.standard_normal((K, d)), jnp.float32)
    w = jnp.asarray(np.full(K, 1.0 / K), jnp.float32)

    glob = jax.jit(lambda f, w: mixed_res_wire_aggregate(f, w, LAM, B))
    seg = jax.jit(lambda f, w: segmented_wire_aggregate(f, w, segments))
    dense = jax.jit(lambda f: segmented_quantize(f, segments))

    t_glob = _time(glob, flat, w)
    t_seg = _time(seg, flat, w)
    t_dense = _time(dense, flat)
    yield csv_row(f"layer_budget/wire_global_K{K}_d{d}", t_glob,
                  "one_global_segment")
    yield csv_row(f"layer_budget/wire_segmented_K{K}_d{d}", t_seg,
                  f"{len(segments)}seg_ratio={t_seg / t_glob:.2f}x")
    yield csv_row(f"layer_budget/dense_segmented_K{K}_d{d}", t_dense,
                  f"{len(segments)}seg_dense_plane")
