"""Benchmark regression gate for CI.

Compares a fresh ``benchmarks.run --json`` output against the committed
baseline (BENCH_baseline.json) and fails when

* any bench group crashed (``status: "error"`` — reported separately
  from slowness), or
* a timed bench (us_per_call > 0 in the baseline) got slower than
  ``factor`` x its baseline (default 2.0; override with --factor or
  the BENCH_GATE_FACTOR env var — CI runners and this container are
  different hardware, so the gate is a coarse smoke bound, not a
  microbenchmark).

Derived-only rows (us_per_call == 0) and the per-group ``_wall`` rows
are compared for presence only, so the structural contract of the
bench suite is also pinned.

    python -m benchmarks.check_regression current.json BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _load(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    # tolerate schema growth: unknown top-level keys (env metadata in
    # "meta", future sections) and records without a name are ignored —
    # the gate only contracts on named bench records
    return {r["name"]: r for r in data.get("benches", [])
            if isinstance(r, dict) and "name" in r}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get("BENCH_GATE_FACTOR",
                                                 "2.0")))
    args = ap.parse_args()

    cur = _load(args.current)
    base = _load(args.baseline)
    crashed, regressed, missing = [], [], []

    for name, rec in cur.items():
        if rec.get("status") == "error":
            crashed.append((name, rec.get("error", "")[-300:]))

    for name, brec in base.items():
        if brec.get("status") != "ok":
            continue
        crec = cur.get(name)
        if crec is None:
            missing.append(name)
            continue
        if name.endswith("/_wall"):
            continue                      # presence-checked only
        if crec.get("status") != "ok":
            continue                      # already counted as crashed
        b_us, c_us = brec.get("us_per_call"), crec.get("us_per_call")
        if not b_us or b_us <= 0 or c_us is None:
            continue                      # derived-only row
        ratio = c_us / b_us
        flag = "REGRESSED" if ratio > args.factor else "ok"
        print(f"{name}: {b_us:.1f}us -> {c_us:.1f}us "
              f"({ratio:.2f}x) {flag}")
        if ratio > args.factor:
            regressed.append((name, ratio))

    ok = True
    if crashed:
        ok = False
        print(f"\nCRASHED ({len(crashed)}):")
        for name, err in crashed:
            print(f"  {name}: {err.splitlines()[-1] if err else '?'}")
    if missing:
        ok = False
        print(f"\nMISSING vs baseline ({len(missing)}): {missing}")
    if regressed:
        ok = False
        print(f"\nSLOW (> {args.factor:.1f}x baseline):")
        for name, ratio in regressed:
            print(f"  {name}: {ratio:.2f}x")
    if not ok:
        sys.exit(1)
    print(f"\nbenchmark gate OK ({len(base)} baseline records, "
          f"factor {args.factor:.1f}x)")


if __name__ == "__main__":
    main()
