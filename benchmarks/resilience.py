"""Checksum + guard overhead on the fused wire path (DESIGN.md §14).

The detection layer adds three things to the packed aggregation: the
head-based finite guard (``head_finite``/``sanitize_head`` — O(K)
reads of the 8-float wire headers, exploiting that H_INF is a
NaN-propagating max|row|), the xor-fold checksum stamped at encode and
verified at decode, and the where-gated weight quarantine.  This bench
compiles the plain ``encode -> reduce`` pipeline and the guarded one
(the exact op sequence the resilient engine step traces) at the
production wire size d = 2^20 and GATES the relative overhead at <5% —
detection must stay effectively free, or it cannot ship always-on.

The gate compares XLA's cost model (``compiled.cost_analysis()`` flops
and bytes-accessed), NOT wall time: repeated paired-median null tests
on this container put the wall-clock noise floor at ~+-7%, which
cannot resolve a 5% ceiling, while the cost model is deterministic for
a fixed program.  Wall times are still reported per row as
informational context.  A third, ungated row records the
injection-ARMED cost — delta-fault wheres + the bit-flip scatter, paid
only by chaos runs that set nonzero fault probabilities.

The gate row carries ``us_per_call=0.0`` (a ratio, not a latency —
the regression gate ratio-checks only positive baselines) and the
group raises when the ceiling is crossed, which the JSON bench
contract records as a per-group error for CI.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import WirePath
from repro.kernels.ops import mixed_res_encode, mixed_res_wire_reduce
from repro.resilience import guards

from .common import csv_row

LAM, B = 0.2, 10
OVERHEAD_CEILING = 0.05


def _compile(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older jax returns [dict]
        cost = cost[0]
    return compiled, float(cost["flops"]), float(cost["bytes accessed"])


def _time(fn, *args, n=8):
    fn(*args)
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(quick: bool = True):
    K = 8 if quick else 20
    d = 1048576
    rng = np.random.default_rng(0)
    flat = jnp.asarray(rng.standard_normal((K, d)), jnp.float32)
    w = jnp.asarray(np.full(K, 1.0 / K), jnp.float32)
    wp = WirePath(plane="packed")
    wp_chk = WirePath(plane="packed", checksum=True)
    faults = {k: jnp.asarray(v)
              for k, v in guards.zero_fault_arrays(K).items()}

    def plain(f, wgt):
        wire = mixed_res_encode(f, LAM, B, path=wp)
        return mixed_res_wire_reduce(wire, wgt, B, d, path=wp)

    def detect(f, wgt):
        wire = mixed_res_encode(f, LAM, B, path=wp_chk)
        good = guards.head_finite(wire)
        wire = guards.sanitize_head(wire, good)
        ok = guards.payload_ok(good, wire, True)
        w_eff, _ = guards.quarantine_weights(wgt, ok)
        return mixed_res_wire_reduce(wire, w_eff, B, d, path=wp_chk)

    def armed(f, wgt, flt):
        f = guards.inject_delta_faults(f, flt)
        wire = mixed_res_encode(f, LAM, B, path=wp_chk)
        wire = guards.inject_bitflips(wire, flt)
        good = guards.head_finite(wire) & ~flt["drop"]
        wire = guards.sanitize_head(wire, good)
        ok = guards.payload_ok(good, wire, True)
        w_eff, _ = guards.quarantine_weights(wgt, ok)
        return mixed_res_wire_reduce(wire, w_eff, B, d, path=wp_chk)

    c_plain, fl_p, by_p = _compile(plain, flat, w)
    c_detect, fl_d, by_d = _compile(detect, flat, w)
    c_armed, fl_a, by_a = _compile(armed, flat, w, faults)
    fl_over = fl_d / fl_p - 1.0
    by_over = by_d / by_p - 1.0

    t_plain = _time(c_plain, flat, w)
    t_detect = _time(c_detect, flat, w)
    t_armed = _time(c_armed, flat, w, faults)

    yield csv_row(f"resilience/wire_plain_K{K}_d{d}", t_plain,
                  f"bytes={by_p:.3e}_flops={fl_p:.3e}")
    yield csv_row(f"resilience/wire_guarded_K{K}_d{d}", t_detect,
                  f"bytes_ratio={by_d / by_p:.3f}x_"
                  f"flops_ratio={fl_d / fl_p:.3f}x")
    yield csv_row(f"resilience/wire_armed_K{K}_d{d}", t_armed,
                  f"bytes_ratio={by_a / by_p:.3f}x_"
                  f"flops_ratio={fl_a / fl_p:.3f}x")
    yield csv_row("resilience/checksum_overhead", 0.0,
                  f"bytes={by_over * 100:.2f}%_flops={fl_over * 100:.2f}"
                  f"%_gate<{OVERHEAD_CEILING * 100:.0f}%")
    if max(by_over, fl_over) > OVERHEAD_CEILING:
        raise RuntimeError(
            f"checksum+guard overhead (bytes {by_over * 100:.2f}%, "
            f"flops {fl_over * 100:.2f}%) exceeds the "
            f"{OVERHEAD_CEILING * 100:.0f}% cost-model ceiling")
