"""Fig. 2 — convergence of FL with mixed-resolution quantization vs
classic FL (non-IID), with the average high-resolution fraction s.

Paper claim: comparable convergence at lambda=0.05 with ~93% overhead
reduction.  Writes runs/bench/fig2.csv (round, scheme, acc, bits).
"""
from __future__ import annotations

import csv
import os

from repro.core.quantize import ClassicQuantizer, MixedResolutionQuantizer
from repro.fl import FLConfig, run_fl

from .common import Timer, csv_row, make_problem, split


def run(T: int = 40, K: int = 8, quick: bool = True, out="runs/bench"):
    os.makedirs(out, exist_ok=True)
    train, test, cfg = make_problem("cifar10-syn",
                                    n_train=3000 if quick else 8000)
    # milder label skew + longer horizon in quick mode: the paper's
    # Fig. 2 runs T=100; below ~T=30 rounds no scheme has converged and
    # the comparison is meaningless
    from repro.data import partition_dirichlet
    shards = partition_dirichlet(train, K, alpha=1.0, seed=0)
    fl = FLConfig(L=5, T=T, batch_size=48, alpha=0.015, eval_every=5)
    rows, summary = [], {}
    for name, q in [
            ("classic", ClassicQuantizer()),
            ("mixed-0.05", MixedResolutionQuantizer(lambda_=0.05, b=10)),
            ("mixed-0.2", MixedResolutionQuantizer(lambda_=0.2, b=10))]:
        with Timer() as t:
            res = run_fl(train, test, shards, cfg, q, None, None, fl)
        for log in res.logs:
            if log.test_acc is not None:
                rows.append([name, log.round, log.test_acc,
                             float(log.bits_per_user.mean())])
        best = max(l.test_acc for l in res.logs if l.test_acc is not None)
        summary[name] = (best, res.mean_bits(), res.mean_s(), t.seconds)
    with open(os.path.join(out, "fig2.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["scheme", "round", "test_acc", "bits_per_user"])
        w.writerows(rows)

    classic_bits = summary["classic"][1]
    lines = []
    for name, (best, bits, s, secs) in summary.items():
        rbar = 100 * (1 - bits / classic_bits)
        lines.append(csv_row(
            f"fig2/{name}", secs * 1e6 / max(fl.T, 1),
            f"best_acc={best:.3f};rbar={rbar:.1f}%;s={100 * s:.2f}%"))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
