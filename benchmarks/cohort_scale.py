"""Streaming-cohort user-axis scaling (PR 8, DESIGN.md §12).

Two claims, measured:

* **Memory**: the traced cohort step's largest d-carrying buffer is
  the cohort stack [C, d] — device residency scales with C, not K.
  Asserted STATICALLY by walking the step's jaxpr at K in {20, 2 000,
  20 000} (tracing is cheap; nothing executes), so the 20 000-user
  point is checked even in quick mode.
* **Time**: one cohort round's wall clock at the K points that fit
  the quick budget (K = 20 000 rides only in --full / the `scale` CI
  suite; ~20-50 s on CPU).

Rows:
  cohort_scale/peak_K{K},0,peak_d_bytes=...;C=...;dense_Kd_bytes=...
  cohort_scale/round_K{K},us_per_round,d=...;C=...;bits_mean=...
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.configs.paper_cnn import PaperCNNConfig
from repro.core.quantize import MixedResolutionQuantizer
from repro.data import make_image_classification
from repro.fl import FLConfig
from repro.sim import EngineConfig, VectorizedFLEngine, WirePath

from .common import csv_row

_COHORT = {20: 8, 2_000: 256, 20_000: 256}


def _engine(K: int) -> VectorizedFLEngine:
    ds = make_image_classification(n_samples=K + 200, hw=8, n_classes=2,
                                   noise=0.3, seed=0)
    train = dataclasses.replace(ds, x=ds.x[:K], y=ds.y[:K])
    test = dataclasses.replace(ds, x=ds.x[K:], y=ds.y[K:])
    shards = [np.array([i]) for i in range(K)]   # one sample per user
    cnn = PaperCNNConfig(input_hw=8, channels=3, conv_filters=4,
                         dense_units=8, n_classes=2)
    fl = FLConfig(T=1, L=1, batch_size=1, seed=0, eval_every=1)
    return VectorizedFLEngine(
        train, test, shards, cnn, MixedResolutionQuantizer(0.2, 10),
        None, None, fl,
        engine=EngineConfig(wire=WirePath(plane="packed",
                                          cohort_size=_COHORT[K])))


def _walk(jaxpr, out):
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(aval)
        for val in eqn.params.values():
            if hasattr(val, "eqns"):
                _walk(val, out)
            elif hasattr(val, "jaxpr"):
                _walk(val.jaxpr, out)
            elif isinstance(val, (tuple, list)):
                for v in val:
                    if hasattr(v, "eqns"):
                        _walk(v, out)
                    elif hasattr(v, "jaxpr"):
                        _walk(v.jaxpr, out)
    return out


def _peak_d_bytes(eng) -> int:
    """Largest intermediate carrying the model dimension d, in bytes,
    from the abstractly traced fused step (nothing executes)."""
    import jax

    sel = np.zeros((eng.K, eng.fl.L, eng.take), dtype=np.int64)
    sds = lambda a: jax.ShapeDtypeStruct(np.shape(a),
                                         np.asarray(a).dtype)
    closed = jax.make_jaxpr(eng._fused_step_fn)(
        jax.tree_util.tree_map(sds, eng.params),
        jax.tree_util.tree_map(sds, eng.qstate),
        sds(eng.dataset.x[sel]), sds(eng.dataset.y[sel]),
        jax.ShapeDtypeStruct((eng.K,), np.float32),
        jax.ShapeDtypeStruct((eng.K,), np.float32))
    avals = _walk(closed.jaxpr, [])
    d = eng.d
    offenders = [a for a in avals if eng.K in a.shape and d in a.shape]
    if offenders:
        raise AssertionError(
            f"[K, d] buffer materialized at K={eng.K}: "
            f"{[a.shape for a in offenders]}")
    return max(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in avals if d in a.shape)


def run(quick: bool = True):
    peaks = {}
    for K in (20, 2_000, 20_000):
        eng = _engine(K)
        C, d = _COHORT[K], eng.d
        peak = _peak_d_bytes(eng)
        peaks[K] = peak
        yield csv_row(f"cohort_scale/peak_K{K}", 0.0,
                      f"peak_d_bytes={peak};C={C};d={d};"
                      f"dense_Kd_bytes={K * d * 4}")
    # the scaling claim itself: same cohort size -> same peak, 10x the
    # users, and the peak is the [C, d] f32 stack, not [K, d]
    assert peaks[2_000] == peaks[20_000], peaks
    assert peaks[20_000] <= _COHORT[20_000] * _engine(20).d * 4, peaks

    for K in (20, 2_000) + (() if quick else (20_000,)):
        eng = _engine(K)
        state = eng.start_run()
        t0 = time.time()
        work = eng.train_round(state, 1)
        import jax
        jax.block_until_ready(state.params)
        dt = time.time() - t0
        assert np.all(np.isfinite(work.bits_np))
        yield csv_row(f"cohort_scale/round_K{K}", dt * 1e6,
                      f"d={eng.d};C={_COHORT[K]};"
                      f"bits_mean={work.bits_np.mean():.1f}")


if __name__ == "__main__":
    for line in run():
        print(line)
