"""Table II — accuracy of mixed-resolution FL vs classic FL on the
three datasets, IID and non-IID (K=20, L=5, b=10, lambda=0.2 in the
paper; reduced K/T in quick mode).

Runs on the repro.sim sweep runner: each (dataset, partition) cell is a
Scenario and the ours-vs-classic pair is a quantizer grid executed on
the vectorized engine.
"""
from __future__ import annotations

import csv
import os

from repro.sim import Scenario, run_grid

from .common import Timer, csv_row


def run(quick: bool = True, out="runs/bench"):
    os.makedirs(out, exist_ok=True)
    K = 6 if quick else 20
    T = 10 if quick else 100
    L = 3 if quick else 5
    batch = 32 if quick else 48
    n_train = 1200 if quick else 8000
    datasets = (["cifar10-syn", "fashion-syn"] if quick
                else ["cifar10-syn", "cifar100-syn", "fashion-syn"])

    quantizers = {
        "ours": ("mixed-resolution", {"lambda_": 0.2, "b": 10}),
        "classic": ("classic", {}),
    }
    lines, rows = [], []
    for ds in datasets:
        for iid in (True, False):
            tag = f"{ds}/{'iid' if iid else 'noniid'}"
            scn = Scenario(
                name=f"table2-{ds}-{'iid' if iid else 'noniid'}",
                description="Table II cell", dataset=ds,
                n_train=n_train, n_test=max(400, n_train // 5),
                partition="iid" if iid else "dirichlet",
                K=K, T=T, L=L, batch_size=batch, lr=0.01, M=None,
                eval_every=5)
            with Timer() as t:
                results = run_grid([scn], quantizers, {"none": None},
                                   quick=False)
            by = {r.cell.quantizer_label: r.summary for r in results}
            b = by["ours"]["best_acc"]
            c = by["classic"]["best_acc"]
            s_pct = 100 * by["ours"]["mean_s"]
            rbar = 100 * (1 - by["ours"]["mean_bits_per_user"]
                          / by["classic"]["mean_bits_per_user"])
            rows.append([tag, b, c, s_pct, rbar])
            lines.append(csv_row(
                f"table2/{tag}", t.seconds * 1e6 / (2 * T),
                f"ours={b:.3f};classic={c:.3f};"
                f"s={s_pct:.2f}%;rbar={rbar:.1f}%"))
    with open(os.path.join(out, "table2.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["setting", "acc_ours", "acc_classic", "s_pct",
                    "rbar_pct"])
        w.writerows(rows)
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
