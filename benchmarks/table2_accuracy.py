"""Table II — accuracy of mixed-resolution FL vs classic FL on the
three datasets, IID and non-IID (K=20, L=5, b=10, lambda=0.2 in the
paper; reduced K/T in quick mode)."""
from __future__ import annotations

import csv
import os

from repro.core.quantize import ClassicQuantizer, MixedResolutionQuantizer
from repro.fl import FLConfig, run_fl

from .common import Timer, csv_row, make_problem, split


def run(quick: bool = True, out="runs/bench"):
    os.makedirs(out, exist_ok=True)
    K = 8 if quick else 20
    T = 20 if quick else 100
    fl = FLConfig(L=5, T=T, batch_size=48, alpha=0.01, eval_every=5)
    lines, rows = [], []
    for ds in (["cifar10-syn", "fashion-syn"] if quick
               else ["cifar10-syn", "cifar100-syn", "fashion-syn"]):
        train, test, cfg = make_problem(ds, n_train=2000 if quick else 8000)
        for iid in (True, False):
            shards = split(train, K, iid=iid)
            with Timer() as t:
                ours = run_fl(train, test, shards, cfg,
                              MixedResolutionQuantizer(lambda_=0.2, b=10),
                              None, None, fl)
                classic = run_fl(train, test, shards, cfg,
                                 ClassicQuantizer(), None, None, fl)
            b = max(l.test_acc for l in ours.logs if l.test_acc is not None)
            c = max(l.test_acc for l in classic.logs
                    if l.test_acc is not None)
            rbar = 100 * (1 - ours.mean_bits() / classic.mean_bits())
            tag = f"{ds}/{'iid' if iid else 'noniid'}"
            rows.append([tag, b, c, 100 * ours.mean_s(), rbar])
            lines.append(csv_row(
                f"table2/{tag}", t.seconds * 1e6 / (2 * T),
                f"ours={b:.3f};classic={c:.3f};"
                f"s={100 * ours.mean_s():.2f}%;rbar={rbar:.1f}%"))
    with open(os.path.join(out, "table2.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["setting", "acc_ours", "acc_classic", "s_pct",
                    "rbar_pct"])
        w.writerows(rows)
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
