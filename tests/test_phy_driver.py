"""Batched grid driver vs the host-solve path (repro.sim.phy_driver).

The churn regression the ISSUE asks for: with partial participation,
the batched path's masked solves must reproduce the engine's
sub-channel semantics (sim/engine.py) round for round — absent users
transmit nothing, interfere with nobody and never straggle.  Training
is identical by construction (same engine, same RNG streams); uplink
latencies agree to the phy parity tolerance of the active precision.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.sim import get_scenario, run_grid, run_grid_batched

# The engine's training stack is float32 (synthetic data + CNN params);
# the global x64 flag would promote the datasets and break the conv
# dtypes.  The x64 CI leg covers the solvers via tests/test_phy_parity
# — this module exercises the f32 production path end to end.
pytestmark = pytest.mark.skipif(
    bool(jax.config.jax_enable_x64),
    reason="engine trains in float32; x64 leg covers solver parity")

LAT_RTOL = 2e-2

QUANTIZERS = {"mixed": ("mixed-resolution", {"lambda_": 0.2, "b": 4}),
              "classic": ("classic", {})}
POWERS = {"ours": "bisection-lp", "maxsum": "max-sum-rate"}


def _tiny(name, **overrides):
    scn = dataclasses.replace(
        get_scenario(name), K=4, T=4, n_train=240, n_test=60,
        batch_size=8, L=1, name=f"{name}-tiny", **overrides)
    return scn


@pytest.fixture(scope="module")
def churn_runs():
    scn = _tiny("churn-0.7", participation=0.5)
    batched = run_grid_batched([scn], QUANTIZERS, POWERS, quick=False)
    host = run_grid([scn], QUANTIZERS, POWERS, quick=False)
    return batched, host


def test_churn_batched_matches_host_logs(churn_runs):
    batched, host = churn_runs
    assert len(batched) == len(host) == 4
    for rb, rh in zip(batched, host):
        assert (rb.cell.quantizer_label, rb.cell.power_label) \
            == (rh.cell.quantizer_label, rh.cell.power_label)
        lb, lh = rb.result.logs, rh.result.logs
        assert len(lb) == len(lh)
        for b, h in zip(lb, lh):
            # training identical: same payloads, same churn draws
            np.testing.assert_array_equal(b.bits_per_user,
                                          h.bits_per_user)
            assert b.test_acc == h.test_acc
            # power control: batched masked solve vs host sub-channel
            np.testing.assert_allclose(b.uplink_latency_s,
                                       h.uplink_latency_s,
                                       rtol=LAT_RTOL)
        np.testing.assert_allclose(
            rb.summary["total_latency_s"], rh.summary["total_latency_s"],
            rtol=LAT_RTOL)


def test_churn_rounds_have_absent_users(churn_runs):
    """The regression is only meaningful if churn actually bit."""
    batched, _ = churn_runs
    logs = batched[0].result.logs
    assert any((log.bits_per_user == 0).any() for log in logs)
    assert all((log.bits_per_user > 0).any() for log in logs)


def test_max_p_metric_reported(churn_runs):
    batched, _ = churn_runs
    for r in batched:
        assert 0.0 < r.summary["max_p"] <= 1.0


def test_run_grid_phy_batched_delegates():
    scn = _tiny("paper-table3")
    res = run_grid([scn], {"classic": ("classic", {})},
                   {"ours": "bisection-lp"}, quick=False,
                   phy_batched=True)
    assert len(res) == 1 and "max_p" in res[0].summary
    assert np.isfinite(res[0].summary["total_latency_s"])


def test_monte_carlo_redraw_batched_matches_host():
    """Per-round channel redraws: the driver re-stacks the bundle from
    each cell's current realization, so redrawn rounds still match the
    host path."""
    scn = _tiny("monte-carlo-channel")
    batched = run_grid_batched([scn], {"classic": ("classic", {})},
                               {"ours": "bisection-lp"}, quick=False)
    host = run_grid([scn], {"classic": ("classic", {})},
                    {"ours": "bisection-lp"}, quick=False)
    ub = [log.uplink_latency_s for log in batched[0].result.logs]
    uh = [log.uplink_latency_s for log in host[0].result.logs]
    assert len(set(np.round(uh, 12))) > 1     # redraws changed latency
    np.testing.assert_allclose(ub, uh, rtol=LAT_RTOL)
