"""Quantizer unit + property tests, incl. Lemma 1 verification."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.quantize import (AquilaQuantizer, ClassicQuantizer,
                                 LAQQuantizer, MixedResolutionQuantizer,
                                 TopQQuantizer, lemma1_bound, make_quantizer,
                                 mixed_resolution_quantize, pack_codes,
                                 pack_signs, static_budget_encode,
                                 static_budget_roundtrip, unpack_codes,
                                 unpack_signs, wire_bits)
from repro.core.quantize.mixed_resolution import lemma1_bound_realized

jax.config.update("jax_enable_x64", False)


def rand_vec(seed, d=4096, scale=1.0):
    rng = np.random.default_rng(seed)
    # heavy-tailed like real gradient deltas: mostly near-zero, few spikes
    x = rng.standard_normal(d) * scale
    spikes = rng.choice(d, size=max(1, d // 100), replace=False)
    x[spikes] *= 50.0
    return jnp.asarray(x, jnp.float32)


# ---------------------------------------------------------------- mixed-res
def dense_spectrum_vec(seed, d=4096):
    """Vector with a dense magnitude spectrum (no gap at any threshold):
    magnitudes uniform in [0, 1] — the regime where the paper's eq. (9)
    holds as printed (dw_q ~= lambda * inf)."""
    rng = np.random.default_rng(seed)
    mags = rng.uniform(0.0, 1.0, d)
    signs = rng.choice([-1.0, 1.0], d)
    return jnp.asarray(mags * signs, jnp.float32)


@pytest.mark.parametrize("lam,b", [(0.05, 10), (0.2, 10), (0.4, 4), (0.8, 2)])
def test_mixed_resolution_lemma1_paper_bound_no_gap(lam, b):
    """Lemma 1 eq. (9) under its implicit no-gap condition.

    With a dense magnitude spectrum dw_q -> lambda*inf and the printed
    constant is valid (small slack for the finite-sample gap)."""
    for seed in range(5):
        x = dense_spectrum_vec(seed)
        res = mixed_resolution_quantize(x, lam, b)
        err = jnp.max(jnp.abs(x - res.recon))
        bound = lemma1_bound(lam, b) * jnp.max(jnp.abs(x))
        # finite-sample gap: dw_q exceeds lambda*inf by <= one order stat
        slack = float(res.aux["dw_q"]) / 2 - lam / 2 * float(res.aux["inf"])
        assert float(err) <= float(bound) + max(slack, 0.0) + 1e-5


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.01, 0.99),
       st.integers(2, 12))
def test_mixed_resolution_lemma1_realized_property(seed, lam, b):
    """Corrected (data-dependent) Lemma 1 holds for ANY input — including
    heavy-tailed vectors with magnitude gaps at the threshold, where the
    paper's printed constant can be exceeded (documented repro finding)."""
    x = rand_vec(seed, d=512)
    res = mixed_resolution_quantize(x, lam, b)
    err = float(jnp.max(jnp.abs(x - res.recon)))
    inf = float(res.aux["inf"])
    rho = float(res.aux["dw_q"]) / inf
    bound = lemma1_bound_realized(lam, b, rho) * inf
    assert err <= bound * (1 + 1e-4)


def test_lemma1_gap_counterexample():
    """Explicit counterexample to eq. (9) as printed: magnitude gap at the
    threshold makes the low-res reconstruction error dw_q/2 > c_j*inf."""
    lam, b = 0.05, 10
    x = jnp.asarray([100.0, 50.0, 0.01], jnp.float32)  # dw_q=50 >> lam*inf=5
    res = mixed_resolution_quantize(x, lam, b)
    err = float(jnp.max(jnp.abs(x - res.recon)))
    paper_bound = lemma1_bound(lam, b) * 100.0
    assert err > paper_bound  # the printed bound fails here...
    rho = float(res.aux["dw_q"]) / 100.0
    assert err <= lemma1_bound_realized(lam, b, rho) * 100.0 * (1 + 1e-5)


def test_mixed_resolution_bit_accounting():
    x = rand_vec(0, d=10000)
    lam, b = 0.2, 10
    res = mixed_resolution_quantize(x, lam, b)
    d = x.size
    s = float(res.aux["s"])
    expected = d * (b * s + 1 - s) + 32
    assert abs(float(res.bits) - expected) < 1e-3
    # adaptive: higher threshold -> fewer high-res -> fewer bits
    res_hi = mixed_resolution_quantize(x, 0.8, b)
    assert float(res_hi.bits) < float(res.bits)


def test_mixed_resolution_zero_vector():
    x = jnp.zeros(128)
    res = mixed_resolution_quantize(x, 0.2, 8)
    assert not jnp.any(jnp.isnan(res.recon))
    np.testing.assert_allclose(res.recon, 0.0)
    assert float(res.bits) == 128 + 32


def test_mixed_resolution_signs_preserved():
    """Low-res elements keep their sign (the paper's key claim vs Top-q)."""
    x = rand_vec(3)
    res = mixed_resolution_quantize(x, 0.4, 4)
    nz = jnp.abs(x) > 0
    assert bool(jnp.all(jnp.where(nz, jnp.sign(res.recon) == jnp.sign(x),
                                  True)))


def test_mixed_resolution_jit_compatible():
    f = jax.jit(lambda v: mixed_resolution_quantize(v, 0.2, 8).recon)
    x = rand_vec(1, d=1024)
    np.testing.assert_allclose(
        f(x), mixed_resolution_quantize(x, 0.2, 8).recon, rtol=1e-6)


# ---------------------------------------------------------------- baselines
def test_classic_identity():
    x = rand_vec(0)
    res, _ = ClassicQuantizer()(x)
    np.testing.assert_allclose(res.recon, x)
    assert float(res.bits) == 32 * x.size


def test_topq_keeps_largest():
    x = rand_vec(0, d=1000)
    res, _ = TopQQuantizer(q=0.01)(x)
    kept = jnp.sum(res.recon != 0)
    assert int(kept) >= 10  # >= k (ties allowed)
    # all kept entries exact
    mask = res.recon != 0
    np.testing.assert_allclose(jnp.where(mask, x, 0.0), res.recon)


def test_topq_topk_threshold_matches_sort():
    """The O(d log k) lax.top_k threshold equals the old full-sort
    k-th order statistic — same recon/bits at small d, ties included."""
    from repro.core.quantize.topq import topq_quantize
    for seed, d, q in [(0, 97, 0.05), (1, 256, 0.01), (2, 512, 0.1)]:
        x = rand_vec(seed, d=d)
        res = topq_quantize(x, q)
        absx = jnp.abs(x)
        k = max(1, int(math.ceil(q * d)))
        thresh_sort = jnp.sort(absx)[d - k]
        recon_sort = jnp.where(absx >= thresh_sort, x, 0.0)
        np.testing.assert_array_equal(np.asarray(res.recon),
                                      np.asarray(recon_sort))
    # explicit tie at rank k: both formulations keep every tied element
    x = jnp.asarray([3.0, -3.0, 3.0, 0.5, -0.1, 0.0], jnp.float32)
    res = topq_quantize(x, 2 / 6)
    np.testing.assert_array_equal(
        np.asarray(res.recon), np.asarray([3.0, -3.0, 3.0, 0, 0, 0]))


def test_laq_skips_and_state():
    qz = LAQQuantizer(b=4, xi=1e6)  # huge xi -> always lazy after round 1
    x = rand_vec(0, d=256)
    state = qz.init_state(256)
    res1, state = qz(x, state)
    assert float(res1.bits) > 0  # first round transmits
    res2, state = qz(x * 1.001, state)
    assert float(res2.bits) == 0.0  # lazy skip
    np.testing.assert_allclose(res2.recon, res1.recon)


def test_laq_error_bounded():
    qz = LAQQuantizer(b=8, xi=0.0)  # never skip
    x = rand_vec(1, d=512)
    res, _ = qz(x, qz.init_state(512))
    r = float(jnp.max(jnp.abs(x)))
    step = r / (2 ** 7 - 1)
    assert float(jnp.max(jnp.abs(res.recon - x))) <= step / 2 + 1e-6


def test_aquila_adapts_bits():
    qz = AquilaQuantizer(b_min=2, b_max=8, tol=0.05)
    x = rand_vec(0, d=512)
    res, _ = qz(x)
    assert 2 <= int(res.aux["b_selected"]) <= 8
    assert float(res.aux["rel_err"]) <= 0.05 or int(res.aux["b_selected"]) == 8


def test_registry():
    for name in ["mixed-resolution", "classic", "laq", "aquila", "top-q"]:
        q = make_quantizer(name)
        assert q.name == name
    with pytest.raises(KeyError):
        make_quantizer("nope")


# ---------------------------------------------------------------- packing
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 300))
def test_sign_pack_roundtrip(seed, d):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(d), jnp.float32)
    signs = unpack_signs(pack_signs(x), d)
    expect = np.where(np.asarray(x) > 0, 1.0, -1.0)
    np.testing.assert_array_equal(np.asarray(signs), expect)


@pytest.mark.parametrize("b", [2, 4, 8, 16])
def test_code_pack_roundtrip(b):
    rng = np.random.default_rng(b)
    n = 173
    codes = jnp.asarray(rng.integers(0, 2 ** b, n), jnp.uint32)
    out = unpack_codes(pack_codes(codes, b), b, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def test_pack_codes_rejects_bad_b():
    with pytest.raises(ValueError):
        pack_codes(jnp.zeros(4, jnp.uint32), 3)


# ---------------------------------------------------------------- static
@pytest.mark.parametrize("b", [2, 4, 8])
def test_static_budget_matches_dynamic_semantics(b):
    """Static top-k budget == dynamic threshold when k = realized dbar."""
    x = rand_vec(0, d=2048)
    k = 64
    recon = static_budget_roundtrip(x, k, b)
    # high-res set: top-k magnitudes are reconstructed on the b-bit grid
    absx = jnp.abs(x)
    vals, idx = jax.lax.top_k(absx, k)
    dw_q, inf = vals[-1], vals[0]
    step = (inf - dw_q) / (2 ** b - 1)
    err_hi = jnp.max(jnp.abs(recon[idx] - x[idx]))
    assert float(err_hi) <= float(step) / 2 + 1e-5
    # low-res: +- dw_q/2 with correct sign
    mask = jnp.ones_like(x, bool).at[idx].set(False)
    lo = recon[mask]
    np.testing.assert_allclose(jnp.abs(lo), float(dw_q) / 2, rtol=1e-6)
    assert bool(jnp.all(jnp.sign(lo) == jnp.where(x[mask] > 0, 1.0, -1.0)))


def test_static_budget_lemma1_with_realized_lambda():
    x = rand_vec(5, d=4096)
    k, b = 128, 4
    recon = static_budget_roundtrip(x, k, b)
    vals, _ = jax.lax.top_k(jnp.abs(x), k)
    lam_eff = float(vals[-1] / vals[0])
    bound = lemma1_bound(lam_eff, b) * float(vals[0])
    assert float(jnp.max(jnp.abs(recon - x))) <= bound * (1 + 1e-4)


def test_wire_bits_smaller_than_classic():
    d, k, b = 1_000_000, 10_000, 4
    assert wire_bits(d, k, b) < 0.05 * (32 * d)  # >95% reduction


def test_static_budget_jit():
    x = rand_vec(2, d=1024)
    f = jax.jit(lambda v: static_budget_roundtrip(v, 32, 4))
    np.testing.assert_allclose(f(x), static_budget_roundtrip(x, 32, 4),
                               rtol=1e-6)
