"""Async straggler-faithful round engine (repro.sim, DESIGN.md §11).

The contract the ISSUE pins:

* sync reduction — ``async_mode=True`` with no deadline runs the
  lockstep engine BIT-FOR-BIT (the async machinery is gated on
  ``EngineConfig.async_active``, so the code path is identical); a
  huge finite deadline reduces semantically (every upload beats the
  deadline, so the event clock reproduces lockstep weights/latency);
* staleness weights are a convex combination — non-negative, sum to 1
  over the arrived set whenever anything arrived (all-zero otherwise);
* churn-during-upload — a user who drops mid-upload is evicted from
  the in-flight buffer and never aggregated;
* upload conservation — every started upload is eventually aggregated,
  dropped (stale or churn) or still in flight;
* O(1) device dispatches per round regardless of K and R;
* the staleness sweep axes run through ``run_grid_batched`` with
  finite ci95 columns (the replicate-axis acceptance criterion).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.sim import (StalenessConfig, VectorizedFLEngine,
                       advance_async_clock, async_scenarios,
                       get_scenario, run_grid_batched,
                       staleness_weights, straggler_gap)
from repro.sim.scenarios import build_problem

from _hypothesis_compat import given, settings, st

pytestmark = pytest.mark.skipif(
    bool(jax.config.jax_enable_x64),
    reason="engine trains in float32; x64 leg covers solver parity")

QUANTIZERS = {"mixed": ("mixed-resolution", {"lambda_": 0.2, "b": 4}),
              "classic": ("classic", {})}
POWERS = {"ours": "bisection-lp", "maxsum": "max-sum-rate"}


def _tiny(name, **overrides):
    fields = dict(K=4, T=4, n_train=240, n_test=60, batch_size=8, L=1,
                  name=f"{name}-tiny")
    fields.update(overrides)
    return dataclasses.replace(get_scenario(name), **fields)


def _engine(scn):
    from repro.core.power import make_power_controller
    from repro.core.quantize import make_quantizer
    from repro.fl.loop import FLConfig

    train, test, shards, cnn_cfg, chan = build_problem(scn)
    fl = FLConfig(L=scn.L, T=scn.T, batch_size=scn.batch_size,
                  seed=scn.seed, eval_every=scn.effective_eval_every)
    return VectorizedFLEngine(
        train, test, shards, cnn_cfg,
        make_quantizer("mixed-resolution", lambda_=0.2, b=4),
        make_power_controller("bisection-lp"), chan, fl,
        engine=scn.engine_config())


def _assert_logs_identical(a, b):
    assert len(a) == len(b)
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(la.bits_per_user, lb.bits_per_user)
        assert la.test_acc == lb.test_acc
        assert la.mean_s == lb.mean_s
        assert la.uplink_latency_s == lb.uplink_latency_s
        assert la.cum_latency_s == lb.cum_latency_s


# ------------------------------------------------------ sync reduction
@pytest.fixture(scope="module")
def sync_reduction_runs():
    base = _tiny("churn-0.7", participation=0.5)
    async_ = dataclasses.replace(base, name="async-red-tiny",
                                 async_mode=True)
    lockstep = run_grid_batched([base], QUANTIZERS, POWERS, quick=False)
    reduced = run_grid_batched([async_], QUANTIZERS, POWERS, quick=False)
    return lockstep, reduced


def test_sync_reduction_bit_for_bit(sync_reduction_runs):
    """The acceptance criterion: async_mode=True with no deadline is
    the lockstep engine bit-for-bit (same code path, gated on
    ``EngineConfig.async_active``)."""
    lockstep, reduced = sync_reduction_runs
    assert len(lockstep) == len(reduced) == 4
    for rl, rr in zip(lockstep, reduced):
        assert (rl.cell.quantizer_label, rl.cell.power_label) \
            == (rr.cell.quantizer_label, rr.cell.power_label)
        _assert_logs_identical(rl.result.logs, rr.result.logs)


def test_sync_reduction_bit_for_bit_replicated(sync_reduction_runs):
    """Same reduction through the replicated (R=2) driver."""
    base = _tiny("churn-0.7", participation=0.5)
    async_ = dataclasses.replace(base, name="async-red2-tiny",
                                 async_mode=True)
    Q = {"mixed": QUANTIZERS["mixed"]}
    P = {"ours": "bisection-lp"}
    a = run_grid_batched([base], Q, P, quick=False, replicates=2)
    b = run_grid_batched([async_], Q, P, quick=False, replicates=2)
    for res_a, res_b in zip(a[0].result, b[0].result):
        _assert_logs_identical(res_a.logs, res_b.logs)


def test_infinite_deadline_is_sync():
    """deadline_s=inf is the documented explicit spelling of the sync
    reduction — StalenessConfig classifies it as sync."""
    assert StalenessConfig(deadline_s=float("inf")).is_sync
    assert StalenessConfig().is_sync
    assert not StalenessConfig(deadline_s=1.0).is_sync
    assert not StalenessConfig(deadline_quantile=0.5).is_sync


def test_huge_finite_deadline_reduces_semantically():
    """With a finite deadline no upload ever misses, the event clock's
    round time equals the lockstep straggler latency and every weight
    is a fresh arrival — lockstep semantics through the genuinely
    async machinery (allclose, not bit-for-bit: aggregation order
    differs)."""
    base = _tiny("churn-0.7", participation=0.5, aggregation="dense")
    async_ = dataclasses.replace(base, name="async-huge-tiny",
                                 async_mode=True, deadline_s=1e9)
    Q = {"mixed": QUANTIZERS["mixed"]}
    P = {"ours": "bisection-lp"}
    a = run_grid_batched([base], Q, P, quick=False)[0]
    b = run_grid_batched([async_], Q, P, quick=False)[0]
    for la, lb in zip(a.result.logs, b.result.logs):
        np.testing.assert_array_equal(la.bits_per_user, lb.bits_per_user)
        np.testing.assert_allclose(lb.uplink_latency_s,
                                   la.uplink_latency_s, rtol=1e-6)
    assert b.summary["mean_staleness"] == 0.0
    assert b.summary["dropped_uploads"] == 0.0
    np.testing.assert_allclose(b.summary["final_acc"],
                               a.summary["final_acc"], atol=5e-2)


# ------------------------------------------- staleness weight property
def _check_convex(w, arrived):
    assert np.all(w >= 0.0)
    np.testing.assert_array_equal(w * ~np.asarray(arrived, bool), 0.0)
    tot = w.sum(axis=-1)
    any_arrived = np.asarray(arrived, bool).any(axis=-1)
    np.testing.assert_allclose(tot[any_arrived], 1.0, rtol=1e-12)
    np.testing.assert_array_equal(tot[~any_arrived], 0.0)


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 16),
       st.floats(0.0, 8.0, allow_nan=False))
def test_staleness_weights_convex_combination_hypothesis(seed, K, alpha):
    """Property: for any rho > 0, staleness >= 0 and arrival mask, the
    weights are a convex combination over the arrived set."""
    rng = np.random.default_rng(seed)
    rho = rng.uniform(0.05, 3.0, size=K)
    staleness = rng.integers(0, 5, size=(3, K))
    arrived = rng.uniform(size=(3, K)) < 0.5
    _check_convex(staleness_weights(rho, staleness, arrived, alpha),
                  arrived)


@pytest.mark.parametrize("seed", range(20))
def test_staleness_weights_convex_combination_seeded(seed):
    """The same property on a fixed seed battery, so the contract is
    exercised even without hypothesis installed."""
    rng = np.random.default_rng(seed)
    K = int(rng.integers(1, 16))
    alpha = float(rng.uniform(0.0, 8.0))
    rho = rng.uniform(0.05, 3.0, size=K)
    staleness = rng.integers(0, 5, size=(4, K))
    arrived = rng.uniform(size=(4, K)) < 0.5
    _check_convex(staleness_weights(rho, staleness, arrived, alpha),
                  arrived)


def test_staleness_weights_downweight_monotone():
    """Higher staleness never gets a larger weight than lower
    staleness at equal rho, and alpha=0 ignores staleness."""
    rho = np.ones(3)
    staleness = np.array([[0, 1, 2]])
    arrived = np.ones((1, 3), bool)
    w = staleness_weights(rho, staleness, arrived, alpha=1.0)[0]
    assert w[0] > w[1] > w[2]
    w0 = staleness_weights(rho, staleness, arrived, alpha=0.0)[0]
    np.testing.assert_allclose(w0, 1.0 / 3.0)


def test_straggler_gap_definition():
    per_user = np.array([1.0, 5.0, 2.0, 9.0])
    mask = np.array([1, 1, 1, 0])
    assert straggler_gap(per_user, mask) == 5.0 - 2.0
    assert straggler_gap(per_user, np.zeros(4)) == 0.0


# --------------------------------------------------- event-clock unit
def _cfg(**kw):
    return StalenessConfig(**kw)


def test_clock_deadline_closes_round_and_buffers_misses():
    """Two fresh uploads, deadline between them: the fast one arrives,
    the slow one enters the buffer with its remaining time."""
    Z = np.zeros((1, 2))
    step = advance_async_clock(
        in_flight=Z.astype(bool), remaining_s=Z.copy(),
        staleness=Z.astype(int), ell=np.array([[1.0, 4.0]]),
        fresh=np.ones((1, 2), bool), participating=np.ones((1, 2), bool),
        rho=np.ones(2), cfg=_cfg(deadline_s=2.0, max_staleness=2))
    assert step.round_s[0] == 2.0
    np.testing.assert_array_equal(step.arrived, [[True, False]])
    np.testing.assert_array_equal(step.in_flight, [[False, True]])
    np.testing.assert_allclose(step.remaining_s, [[0.0, 2.0]])
    np.testing.assert_array_equal(step.staleness, [[0, 1]])
    assert step.w_fresh[0, 0] == 1.0 and step.w_buf.sum() == 0.0


def test_clock_buffered_upload_arrives_with_staleness_weight():
    """A buffered upload finishing inside the deadline aggregates with
    weight (1+s)^-alpha relative to a fresh arrival."""
    step = advance_async_clock(
        in_flight=np.array([[True, False]]),
        remaining_s=np.array([[0.5, 0.0]]),
        staleness=np.array([[1, 0]]), ell=np.array([[0.0, 1.0]]),
        fresh=np.array([[False, True]]),
        participating=np.ones((1, 2), bool), rho=np.ones(2),
        cfg=_cfg(deadline_s=2.0, alpha=1.0, max_staleness=2))
    np.testing.assert_array_equal(step.arrived, [[True, True]])
    # fresh weight 1, buffered weight (1+1)^-1 = 0.5, normalized
    np.testing.assert_allclose(step.w_buf[0, 0], 0.5 / 1.5)
    np.testing.assert_allclose(step.w_fresh[0, 1], 1.0 / 1.5)
    np.testing.assert_array_equal(step.arrived_staleness, [[1, 0]])


def test_clock_churn_during_upload_drops_in_flight():
    """The regression the ISSUE names: a user who drops out mid-upload
    is evicted — never aggregated, never kept in the buffer."""
    step = advance_async_clock(
        in_flight=np.array([[True, False]]),
        remaining_s=np.array([[0.1, 0.0]]),
        staleness=np.array([[1, 0]]), ell=np.array([[0.0, 1.0]]),
        fresh=np.array([[False, True]]),
        participating=np.array([[False, True]]),   # user 0 churned out
        rho=np.ones(2), cfg=_cfg(deadline_s=5.0, max_staleness=3))
    assert step.dropped_churn[0] == 1
    assert not step.arrived[0, 0] and not step.in_flight[0, 0]
    assert step.w_buf[0, 0] == 0.0
    assert step.arrived[0, 1]           # the fresh upload still lands


def test_clock_bounded_staleness_drops():
    """An upload that misses max_staleness deadlines is dropped, and
    max_staleness=0 drops fresh misses outright."""
    step = advance_async_clock(
        in_flight=np.array([[True]]), remaining_s=np.array([[9.0]]),
        staleness=np.array([[2]]), ell=np.array([[0.0]]),
        fresh=np.array([[False]]), participating=np.array([[True]]),
        rho=np.ones(1), cfg=_cfg(deadline_s=1.0, max_staleness=2))
    assert step.dropped_stale[0] == 1 and not step.in_flight[0, 0]

    step0 = advance_async_clock(
        in_flight=np.zeros((1, 2), bool), remaining_s=np.zeros((1, 2)),
        staleness=np.zeros((1, 2), int), ell=np.array([[1.0, 9.0]]),
        fresh=np.ones((1, 2), bool), participating=np.ones((1, 2), bool),
        rho=np.ones(2), cfg=_cfg(deadline_s=2.0, max_staleness=0))
    assert step0.dropped_stale[0] == 1
    assert not step0.in_flight.any()


def test_clock_quantile_deadline_and_all_idle_round():
    """deadline_quantile closes at that quantile of pending completion
    times; a round with nothing pending is a zero-duration no-op."""
    step = advance_async_clock(
        in_flight=np.zeros((1, 4), bool), remaining_s=np.zeros((1, 4)),
        staleness=np.zeros((1, 4), int),
        ell=np.array([[1.0, 2.0, 3.0, 4.0]]),
        fresh=np.ones((1, 4), bool), participating=np.ones((1, 4), bool),
        rho=np.ones(4), cfg=_cfg(deadline_quantile=0.5, max_staleness=2))
    np.testing.assert_allclose(step.round_s, [2.5])
    assert step.arrived.sum() == 2
    np.testing.assert_allclose(step.straggler_gap_s, [4.0 - 2.5])

    idle = advance_async_clock(
        in_flight=np.zeros((1, 2), bool), remaining_s=np.zeros((1, 2)),
        staleness=np.zeros((1, 2), int), ell=np.zeros((1, 2)),
        fresh=np.zeros((1, 2), bool),
        participating=np.ones((1, 2), bool), rho=np.ones(2),
        cfg=_cfg(deadline_quantile=0.5, max_staleness=2))
    assert idle.round_s[0] == 0.0 and not idle.arrived.any()
    assert idle.w_fresh.sum() == 0.0 and idle.w_buf.sum() == 0.0


# --------------------------------------- integration: conservation law
@pytest.mark.parametrize("aggregation", ["dense", "wire"])
def test_upload_conservation_under_churn(aggregation):
    """Every upload ever started is aggregated, dropped (stale/churn)
    or still in flight at the end — nothing is double-counted, and a
    churn run actually exercises the churn-drop branch."""
    scn = _tiny("async-churn", T=6, aggregation=aggregation,
                participation=0.6)
    eng = _engine(scn)
    state = eng.start_run()
    for t in range(1, scn.T + 1):
        work = eng.train_round(state, t)
        up, pu = eng.solve_uplink_host_detailed(
            state.chan, work.bits_np, work.active)
        info = eng.complete_round_async(state, work, pu)
        eng.finish_round(state, work, up, async_info=info,
                         per_user_s=pu)
    clock = state.async_clock
    assert clock.uploads_started > 0
    assert clock.uploads_started == (clock.arrived_total
                                     + clock.dropped_stale
                                     + clock.dropped_churn
                                     + int(clock.in_flight.sum()))


def test_busy_users_do_not_start_fresh_uploads():
    """At most one in-flight upload per user: a user parked in the
    buffer is excluded from the fresh-uploader mask."""
    scn = _tiny("async-q50", T=5)
    eng = _engine(scn)
    state = eng.start_run()
    for t in range(1, scn.T + 1):
        busy_before = state.async_clock.in_flight[0].copy()
        work = eng.train_round(state, t)
        assert not np.any((work.active > 0) & busy_before)
        up, pu = eng.solve_uplink_host_detailed(
            state.chan, work.bits_np, work.active)
        info = eng.complete_round_async(state, work, pu)
        eng.finish_round(state, work, up, async_info=info,
                         per_user_s=pu)
    assert state.async_clock.arrived_total > 0


def test_finish_round_uses_event_clock_latency():
    """The latency-accounting fix: an async round's logged uplink
    latency is the event-clock round duration, not the full straggler
    solve latency."""
    scn = _tiny("async-q50", T=3)
    eng = _engine(scn)
    state = eng.start_run()
    work = eng.train_round(state, 1)
    up, pu = eng.solve_uplink_host_detailed(
        state.chan, work.bits_np, work.active)
    info = eng.complete_round_async(state, work, pu)
    eng.finish_round(state, work, up, async_info=info, per_user_s=pu)
    log = state.logs[-1]
    assert log.uplink_latency_s == float(info.round_uplink_s[0])
    # quantile deadline < max completion => strictly under the
    # lockstep straggler latency
    assert log.uplink_latency_s < up
    assert log.effective_participation == \
        float(info.effective_participation[0])


# -------------------------------------------------- dispatch counting
@pytest.mark.parametrize("R", [None, 4])
def test_async_constant_dispatches_per_round(monkeypatch, R):
    """One async train dispatch + one aggregate dispatch per round
    regardless of K and the replicate count."""
    calls = {"train": 0, "agg": 0}
    orig = VectorizedFLEngine._async_steps

    def counting(self, n=None):
        train, agg = orig(self, n)

        def ctrain(*a, **k):
            calls["train"] += 1
            return train(*a, **k)

        def cagg(*a, **k):
            calls["agg"] += 1
            return agg(*a, **k)
        return ctrain, cagg

    monkeypatch.setattr(VectorizedFLEngine, "_async_steps", counting)
    T = 3
    scn = _tiny("async-q50", T=T)
    if R is None:
        run_grid_batched([scn], {"mixed": QUANTIZERS["mixed"]},
                         {"ours": "bisection-lp"}, quick=False)
    else:
        run_grid_batched([scn], {"mixed": QUANTIZERS["mixed"]},
                         {"ours": "bisection-lp"}, quick=False,
                         replicates=R)
    assert calls["train"] == T
    assert calls["agg"] == T


# ---------------------------------------- sweep axes + ci95 (replicas)
def test_async_sweep_axes_with_replicates():
    """The acceptance criterion: the staleness sweep axes
    (alpha x deadline-quantile x buffer-depth) run through
    run_grid_batched(replicates=R) and report finite ci95 columns."""
    base = _tiny("async-q50", T=3)
    scns = async_scenarios(alphas=(0.0, 1.0), quantiles=(0.5,),
                           depths=(1, 2), base=base)
    assert [s.name for s in scns] == [
        "async-a0-q0.5-d1", "async-a0-q0.5-d2",
        "async-a1-q0.5-d1", "async-a1-q0.5-d2"]
    res = run_grid_batched(scns, {"mixed": QUANTIZERS["mixed"]},
                           {"ours": "bisection-lp"}, quick=False,
                           replicates=2)
    assert len(res) == 4
    for r in res:
        s = r.summary
        assert s["replicates"] == 2.0
        for key in ("final_acc", "total_latency_s", "mean_staleness",
                    "effective_participation", "mean_straggler_gap_s"):
            assert np.isfinite(s[key]), key
            assert np.isfinite(s[key + "_ci95"]), key + "_ci95"
        assert 0.0 < s["effective_participation"] <= 1.0


def test_depth_axis_changes_drop_accounting():
    """Buffer depth is a live axis: depth 0 (drop every miss) records
    strictly more dropped uploads than a deep buffer on the same
    workload."""
    base = _tiny("async-q50", T=4)
    shallow = dataclasses.replace(base, name="async-d0-tiny",
                                  max_staleness=0)
    deep = dataclasses.replace(base, name="async-d4-tiny",
                               max_staleness=4)
    Q = {"mixed": QUANTIZERS["mixed"]}
    P = {"ours": "bisection-lp"}
    rs = run_grid_batched([shallow], Q, P, quick=False)[0].summary
    rd = run_grid_batched([deep], Q, P, quick=False)[0].summary
    assert rs["dropped_uploads"] > rd["dropped_uploads"]
    assert rs["mean_staleness"] == 0.0   # nothing survives to be stale


# --------------------------------------------------------- validation
def test_staleness_config_validation():
    with pytest.raises(ValueError):
        StalenessConfig(deadline_s=1.0, deadline_quantile=0.5)
    with pytest.raises(ValueError):
        StalenessConfig(deadline_s=-1.0)
    with pytest.raises(ValueError):
        StalenessConfig(deadline_quantile=1.5)
    with pytest.raises(ValueError):
        StalenessConfig(alpha=-0.1)
    with pytest.raises(ValueError):
        StalenessConfig(max_staleness=-1)


def test_async_rejects_signplane_and_unfused():
    from repro.sim import EngineConfig
    scn = _tiny("async-q50", aggregation="signplane")
    with pytest.raises(ValueError, match="wire"):
        _engine(scn)
    cfg = EngineConfig(async_mode=True, fused=False,
                       staleness=StalenessConfig(deadline_quantile=0.5))
    scn2 = _tiny("async-q50")
    train, test, shards, cnn_cfg, chan = build_problem(scn2)
    from repro.core.quantize import make_quantizer
    from repro.fl.loop import FLConfig
    with pytest.raises(ValueError, match="fused"):
        VectorizedFLEngine(train, test, shards, cnn_cfg,
                           make_quantizer("classic"), None, chan,
                           FLConfig(L=1, T=1, batch_size=8, seed=0),
                           engine=cfg)
