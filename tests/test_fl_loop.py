"""End-to-end FL simulation tests (small but real training)."""
import numpy as np
import pytest

from repro.configs.paper_cnn import PaperCNNConfig
from repro.core.channel import CFmMIMOConfig, make_channel
from repro.core.power import BisectionLPPowerControl
from repro.core.quantize import (ClassicQuantizer, MixedResolutionQuantizer,
                                 TopQQuantizer)
from repro.data import (make_image_classification, partition_dirichlet,
                        partition_iid, user_fractions)
from repro.fl import FLConfig, run_fl


@pytest.fixture(scope="module")
def problem():
    full = make_image_classification(n_samples=1600, hw=16, n_classes=4,
                                     noise=0.25, seed=0)
    train_idx, test_idx = np.arange(1200), np.arange(1200, 1600)
    import dataclasses
    train = dataclasses.replace(full, x=full.x[train_idx],
                                y=full.y[train_idx])
    test = dataclasses.replace(full, x=full.x[test_idx], y=full.y[test_idx])
    cfg = PaperCNNConfig(input_hw=16, n_classes=4)
    return train, test, cfg


def test_partitions(problem):
    train, _, _ = problem
    iid = partition_iid(train, 8)
    assert sum(len(s) for s in iid) == len(train)
    assert len(np.unique(np.concatenate(iid))) == len(train)  # disjoint
    nid = partition_dirichlet(train, 8, alpha=0.3)
    assert sum(len(s) for s in nid) == len(train)
    rho = user_fractions(nid)
    np.testing.assert_allclose(rho.sum(), 1.0)
    # non-IID should be more label-skewed than IID
    def skew(shards):
        fr = []
        for s in shards:
            counts = np.bincount(train.y[s], minlength=4) / len(s)
            fr.append(counts.max())
        return np.mean(fr)
    assert skew(nid) > skew(iid)


def test_fl_learns_with_mixed_resolution(problem):
    train, test, cfg = problem
    shards = partition_iid(train, 8)
    fl = FLConfig(L=5, T=16, batch_size=48, alpha=0.01, eval_every=8,
                  seed=0)
    res = run_fl(train, test, shards, cfg,
                 MixedResolutionQuantizer(lambda_=0.05, b=10),
                 power=None, chan=None, fl=fl)
    best = max(l.test_acc for l in res.logs if l.test_acc is not None)
    assert best > 0.5            # 4 classes, chance = 0.25
    assert res.mean_s() < 0.6    # adaptivity: not everything high-res


def test_mixed_resolution_tracks_classic(problem):
    """Fig. 2 claim: mixed-resolution ~ classic FL accuracy, >>fewer bits."""
    train, test, cfg = problem
    shards = partition_iid(train, 8)
    fl = FLConfig(L=5, T=20, batch_size=48, alpha=0.01, eval_every=5)
    r_classic = run_fl(train, test, shards, cfg, ClassicQuantizer(),
                       None, None, fl)
    r_mixed = run_fl(train, test, shards, cfg,
                     MixedResolutionQuantizer(lambda_=0.05, b=10),
                     None, None, fl)

    def best(r):
        return max(l.test_acc for l in r.logs if l.test_acc is not None)

    # comparable accuracy (small-model FL runs are noisy; the full
    # benchmark in benchmarks/fig2_convergence.py runs the real horizon)
    assert best(r_mixed) >= best(r_classic) - 0.12
    assert r_mixed.mean_bits() < 0.15 * r_classic.mean_bits()  # >85% saved


def test_fl_with_power_control_latency(problem):
    train, test, cfg = problem
    shards = partition_dirichlet(train, 8, alpha=0.5)
    chan = make_channel(CFmMIMOConfig(K=8), seed=0)
    fl = FLConfig(L=2, T=4, batch_size=16, eval_every=4,
                  latency_budget_s=None)
    res = run_fl(train, test, shards, cfg,
                 MixedResolutionQuantizer(lambda_=0.2, b=10),
                 BisectionLPPowerControl(), chan, fl)
    assert all(l.uplink_latency_s > 0 for l in res.logs)
    assert res.logs[-1].cum_latency_s > 0


def test_fl_latency_budget_caps_rounds(problem):
    train, test, cfg = problem
    shards = partition_iid(train, 8)
    chan = make_channel(CFmMIMOConfig(K=8), seed=0)
    fl_unlim = FLConfig(L=2, T=6, batch_size=16, eval_every=6)
    r1 = run_fl(train, test, shards, cfg, ClassicQuantizer(),
                BisectionLPPowerControl(), chan, fl_unlim)
    budget = r1.logs[2].cum_latency_s  # allow ~3 rounds
    fl_budget = FLConfig(L=2, T=6, batch_size=16, eval_every=6,
                         latency_budget_s=budget)
    r2 = run_fl(train, test, shards, cfg, ClassicQuantizer(),
                BisectionLPPowerControl(), chan, fl_budget)
    assert r2.rounds_completed <= 3
