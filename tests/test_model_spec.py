"""ModelSpec battery: the pytree-generic engine contract.

The engine's 4th slot accepts any ModelSpec; these tests pin the
PaperCNNConfig back-compat shim, federate the reduced registry
transformer end-to-end (run_fl and the fused engine, with and without
a per-layer budget), and check that non-f32 leaf dtypes survive a
round (the flatten/unflatten dtype fix this PR rides on).

The heavier run_grid smoke is gated behind RUN_MODEL_SUITE=1 (the CI
``models`` suite); everything else rides tier-1.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import PaperCNNConfig
from repro.core.quantize import LayerBudget, MixedResolutionQuantizer
from repro.data.federated import partition_iid
from repro.data.synthetic import make_lm_dataset
from repro.fl import (FLConfig, ModelSpec, as_model_spec,
                      model_spec_from_arch, run_fl)
from repro.kernels import WirePath
from repro.sim import EngineConfig, VectorizedFLEngine

jax.config.update("jax_enable_x64", False)


# ------------------------------------------------------- spec resolution
def test_as_model_spec_cnn_shim():
    cfg = PaperCNNConfig(input_hw=8, n_classes=2, channels=3,
                         conv_filters=4, dense_units=8)
    spec = as_model_spec(cfg)
    assert spec.name == "paper-cnn" and spec.config is cfg
    assert as_model_spec(spec) is spec            # idempotent
    params = spec.init(jax.random.PRNGKey(0))
    x = jnp.zeros((2, 8, 8, 3)); y = jnp.zeros((2,), jnp.int32)
    assert np.isfinite(float(spec.loss(params, x, y)))
    with pytest.raises(TypeError, match="ModelSpec"):
        as_model_spec({"not": "a model"})


def test_model_spec_from_arch_rejects_non_token_models():
    with pytest.raises(ValueError, match="decoder-only"):
        model_spec_from_arch("whisper-base")


@pytest.fixture(scope="module")
def lm_spec():
    return model_spec_from_arch("qwen3-14b")


@pytest.fixture(scope="module")
def lm_problem(lm_spec):
    full = make_lm_dataset(n_samples=48, seq_len=8,
                           vocab=lm_spec.config.vocab_size, seed=0)
    train = dataclasses.replace(full, x=full.x[:32], y=full.y[:32])
    test = dataclasses.replace(full, x=full.x[32:], y=full.y[32:])
    return train, test


def test_make_lm_dataset_shapes(lm_spec):
    ds = make_lm_dataset(n_samples=10, seq_len=8, vocab=32, seed=1)
    assert ds.x.shape == (10, 8) and ds.y.shape == (10,)
    assert ds.n_classes == 32
    assert ds.x.dtype.kind == "i" and int(ds.x.max()) < 32
    # windows really are shifted views of one stream
    np.testing.assert_array_equal(ds.x[1, :-1], ds.x[0, 1:])


# ------------------------------------------------- federated transformer
def test_transformer_run_fl_smoke(lm_spec, lm_problem):
    """ISSUE acceptance: the reduced registry transformer completes a
    federated run through run_fl on CPU."""
    train, test = lm_problem
    shards = partition_iid(train, 2)
    fl = FLConfig(L=1, T=1, batch_size=8, alpha=0.01, eval_every=1,
                  seed=0)
    res = run_fl(train, test, shards, lm_spec,
                 MixedResolutionQuantizer(lambda_=0.2, b=10),
                 None, None, fl)
    assert len(res.logs) == 1
    assert np.isfinite(np.asarray(res.logs[0].bits_per_user)).all()
    assert 0.0 <= res.logs[0].test_acc <= 1.0
    # params keep the transformer treedef
    assert jax.tree_util.tree_structure(res.params) == \
        jax.tree_util.tree_structure(lm_spec.init(jax.random.PRNGKey(0)))


def test_transformer_engine_with_layer_budget(lm_spec, lm_problem):
    """Per-layer budgets resolve against the transformer tree: embed /
    norm / matmul groups all appear and the budgeted fused round runs."""
    train, test = lm_problem
    shards = partition_iid(train, 2)
    fl = FLConfig(L=1, T=1, batch_size=8, alpha=0.01, eval_every=1,
                  seed=0)
    lb = LayerBudget.by_group(embed=(0.4, 4), norm=(0.05, 12),
                              matmul=(0.2, 8))
    eng = VectorizedFLEngine(
        train, test, shards, lm_spec,
        MixedResolutionQuantizer(lambda_=0.2, b=10), None, None, fl,
        engine=EngineConfig(wire=WirePath(plane="dense", budget=lb),
                            fused=True))
    groups = {seg.group for seg in eng._segments}
    assert groups == {"embed", "norm", "matmul"}
    assert sum(seg.size for seg in eng._segments) == eng.d
    res = eng.run()
    np.testing.assert_array_equal(
        np.asarray(res.logs[0].bits_per_user) > 0, True)


# ------------------------------------------------------- dtype survival
def test_custom_spec_bf16_leaves_survive_round():
    """A ModelSpec with bf16 leaves keeps them bf16 after the engine's
    flatten -> aggregate -> unflatten update (satellite 1 end-to-end)."""
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w": jax.random.normal(k1, (4, 2), jnp.bfloat16),
                "b": jnp.zeros((2,), jnp.float32),
                "g": jax.random.normal(k2, (4,), jnp.float16)}

    def loss(params, x, y):
        logits = x @ params["w"].astype(jnp.float32) + params["b"]
        logits = logits * jnp.mean(params["g"].astype(jnp.float32))
        oh = jax.nn.one_hot(y, 2)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * oh, -1))

    def accuracy(params, x, y):
        logits = x @ params["w"].astype(jnp.float32) + params["b"]
        return float(jnp.mean(jnp.argmax(logits, -1) == y))

    spec = ModelSpec(name="toy-bf16", init=init, loss=loss,
                     accuracy=accuracy)
    rng = np.random.default_rng(0)
    from repro.data.synthetic import ImageDataset
    x = rng.standard_normal((32, 4)).astype(np.float32)
    y = (x.sum(-1) > 0).astype(np.int64)
    train = ImageDataset(x=x[:24], y=y[:24], n_classes=2)
    test = ImageDataset(x=x[24:], y=y[24:], n_classes=2)
    shards = partition_iid(train, 2)
    fl = FLConfig(L=1, T=2, batch_size=8, alpha=0.05, eval_every=2,
                  seed=0)
    res = run_fl(train, test, shards, spec,
                 MixedResolutionQuantizer(lambda_=0.2, b=10),
                 None, None, fl)
    assert res.params["w"].dtype == jnp.bfloat16
    assert res.params["g"].dtype == jnp.float16
    assert res.params["b"].dtype == jnp.float32
    # and the update actually moved the bf16 leaves
    p0 = init(jax.random.PRNGKey(fl.seed))
    assert not np.array_equal(np.asarray(res.params["w"], np.float32),
                              np.asarray(p0["w"], np.float32))


# ----------------------------------------------------- run_grid (gated)
@pytest.mark.skipif(os.environ.get("RUN_MODEL_SUITE") != "1",
                    reason="models CI suite only (RUN_MODEL_SUITE=1)")
def test_transformer_run_grid_scenario():
    from repro.sim import run_grid
    res = run_grid(["transformer-fused"],
                   {"mixed": ("mixed-resolution",
                              {"lambda_": 0.2, "b": 10})}, quick=True)
    assert len(res) == 1
    assert np.isfinite(res[0].summary["final_acc"])


@pytest.mark.skipif(os.environ.get("RUN_MODEL_SUITE") != "1",
                    reason="models CI suite only (RUN_MODEL_SUITE=1)")
def test_layer_budget_scenario_registered():
    from repro.sim import run_grid
    res = run_grid(["layer-budget-wire"],
                   {"mixed": ("mixed-resolution",
                              {"lambda_": 0.2, "b": 10})}, quick=True)
    assert len(res) == 1
    assert np.isfinite(res[0].summary["final_acc"])
