"""Driver for the multi-device distributed-runtime checks.

They run in a subprocess because --xla_force_host_platform_device_count
must be set before jax initializes (and only for these checks — the
rest of the suite sees 1 device, per the dry-run isolation rule)."""
import os
import subprocess
import sys

import pytest

# an import failure here must FAIL the suite, not skip it: the checks
# below are the correctness gate of the repro.dist runtime
import repro.dist  # noqa: F401


@pytest.mark.timeout(900)
def test_dist_checks_subprocess():
    script = os.path.join(os.path.dirname(__file__), "dist_checks.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    res = subprocess.run([sys.executable, script], capture_output=True,
                         text=True, env=env, timeout=880)
    sys.stdout.write(res.stdout)
    sys.stderr.write(res.stderr[-4000:])
    assert res.returncode == 0, "dist checks failed"
    assert "ALL DIST CHECKS PASSED" in res.stdout
