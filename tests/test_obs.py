"""repro.obs — jit-safe telemetry (DESIGN.md §10).

The contract the ISSUE pins:

* JSONL event schema: every event is one flat JSON object with the
  ``ts``/``kind``/``name`` envelope plus context tags, and the file
  round-trips through ``repro.obs.report``;
* recompile detector: a probed step function's wrapper body runs once
  per jit cache entry — forcing a retrace is counted, and crossing the
  session's storm threshold flags (and warns about) a retrace storm;
* sim-engine smoke: a tiny batched-driver grid under a session emits
  per-round ``engine.round``/``phy.solve``/``engine.jit_round`` events
  whose values match the returned round logs;
* zero-overhead when disabled: without an active session, ``jit_tap``
  stages NOTHING (no callback in the jaxpr — the compiled program is
  bit-identical to uninstrumented code) and a full grid run returns
  bit-identical round outputs whether or not a session was active.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs.report import (load_events, per_round_table,
                              phase_breakdown, render_report,
                              retrace_summary, wire_summary)
from repro.sim import get_scenario, run_grid_batched

pytestmark = pytest.mark.skipif(
    bool(jax.config.jax_enable_x64),
    reason="engine trains in float32; x64 leg covers solver parity")

QUANTIZERS = {"mixed": ("mixed-resolution", {"lambda_": 0.2, "b": 4})}
POWERS = {"ours": "bisection-lp"}


def _tiny(name, **overrides):
    fields = dict(K=4, T=4, n_train=240, n_test=60, batch_size=8, L=1,
                  name=f"{name}-tiny")
    fields.update(overrides)
    return dataclasses.replace(get_scenario(name), **fields)


# ------------------------------------------------------- event schema
def test_jsonl_event_schema_golden(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    with obs.session(jsonl=path) as sess:
        obs.record("unit.event", x=1, y=2.5, label="a")
        obs.counter("unit.count", 3)
        obs.counter("unit.count")
        with obs.context(scenario="s1", round=7):
            obs.record("unit.tagged", z=np.float32(0.5))
        with obs.scope("unit.phase"):
            pass
    mem = sess.events            # memory sink survives session close

    lines = [json.loads(ln) for ln in open(path) if ln.strip()]
    assert lines == mem                    # both sinks see every event
    by_name = {e["name"]: e for e in lines}

    # envelope: ts/kind/name on every event, session start/end framing
    for e in lines:
        assert isinstance(e["ts"], float)
        assert e["kind"] in ("event", "phase", "jit", "counter",
                             "retrace", "session")
        assert isinstance(e["name"], str)
    assert lines[0] == by_name["start"] and lines[0]["kind"] == "session"
    assert lines[-1] == by_name["end"] and lines[-1]["kind"] == "session"

    ev = by_name["unit.event"]
    assert (ev["kind"], ev["x"], ev["y"], ev["label"]) \
        == ("event", 1, 2.5, "a")
    # context tags ride on every event inside the block
    assert by_name["unit.tagged"]["scenario"] == "s1"
    assert by_name["unit.tagged"]["round"] == 7
    assert by_name["unit.tagged"]["z"] == 0.5
    # counters flush once per name at close, accumulated
    assert by_name["unit.count"]["kind"] == "counter"
    assert by_name["unit.count"]["total"] == 4.0
    assert by_name["unit.phase"]["kind"] == "phase"
    assert by_name["unit.phase"]["dur_s"] >= 0.0
    assert load_events(path) == lines      # report loader round-trips


def test_scalarization_of_array_payloads():
    with obs.session() as sess:
        obs.record("arrays", small=np.arange(3), big=np.zeros(1000),
                   zero_d=np.float64(2.0))
        e = sess.events[-1]
    assert e["small"] == [0, 1, 2]
    assert e["zero_d"] == 2.0
    assert e["big"] == {"min": 0.0, "max": 0.0, "mean": 0.0,
                        "size": 1000}


def test_single_active_session_enforced():
    with obs.session():
        with pytest.raises(RuntimeError, match="already active"):
            with obs.session():
                pass
    assert not obs.enabled()               # cleared even after nesting


# -------------------------------------------------- recompile detector
def test_retrace_probe_counts_jit_cache_misses():
    obs.reset_retrace_counts()
    f = jax.jit(obs.retrace_probe("t.f", lambda x: x * 2))
    f(jnp.ones(3))
    f(jnp.ones(3))                         # cache hit: no wrapper run
    f(jnp.ones(4))                         # shape change: retrace
    assert obs.retrace_counts()["t.f"] == 2


def test_retrace_storm_flagged_and_warned():
    obs.reset_retrace_counts()
    with obs.session(retrace_storm=3) as sess:
        g = jax.jit(obs.retrace_probe("t.storm", lambda x: x + 1))
        g(jnp.ones(1))
        g(jnp.ones(2))
        with pytest.warns(UserWarning, match="retrace storm"):
            g(jnp.ones(3))
        events = [e for e in sess.events if e["kind"] == "retrace"
                  and e["name"] == "t.storm"]
    assert [e["count"] for e in events] == [1, 2, 3]
    assert [e["storm"] for e in events] == [False, False, True]
    assert sess.retraces["t.storm"] == 3
    assert retrace_summary(events)[0]["storm"]


# ------------------------------------------------ jit-safety contract
def test_jit_tap_stages_nothing_without_session():
    # fresh closure per trace: jax caches traces by function identity,
    # which is exactly why the trace-time gate makes sessions have to
    # be entered before the instrumented step is first compiled
    def make_fn():
        def fn(x):
            obs.jit_tap("t.tap", {"m": jnp.mean(x)})
            return x * 2
        return fn

    assert not obs.enabled()
    assert "callback" not in str(jax.make_jaxpr(make_fn())(jnp.ones(4)))
    with obs.session():
        assert "callback" in str(jax.make_jaxpr(make_fn())(jnp.ones(4)))


def test_jit_tap_delivers_values_under_jit():
    with obs.session() as sess:
        def fn(x):
            obs.jit_tap("t.tap", {"m": jnp.mean(x), "n": x.shape[0]})
            return x * 2
        jax.jit(fn)(jnp.arange(4.0)).block_until_ready()
        taps = [e for e in sess.events if e["name"] == "t.tap"]
    assert len(taps) == 1
    assert taps[0]["kind"] == "jit"
    assert taps[0]["m"] == pytest.approx(1.5)
    assert taps[0]["n"] == 4


def test_wire_encode_stages_no_callback_without_session():
    from repro.kernels.ops import mixed_res_wire_aggregate

    def make_agg():
        def agg(flat, w):
            return mixed_res_wire_aggregate(flat, w, 0.5, 4)[0]
        return agg

    flat = jnp.ones((2, 256))
    w = jnp.full((2,), 0.5)
    assert not obs.enabled()
    assert "callback" not in str(jax.make_jaxpr(make_agg())(flat, w))
    with obs.session():
        assert "callback" in str(jax.make_jaxpr(make_agg())(flat, w))


# --------------------------------------------------- sim-engine smoke
@pytest.fixture(scope="module")
def traced_grid():
    scn = _tiny("churn-0.7", participation=0.5)
    baseline = run_grid_batched([scn], QUANTIZERS, POWERS, quick=False)
    with obs.session() as sess:
        traced = run_grid_batched([scn], QUANTIZERS, POWERS,
                                  quick=False)
        events = list(sess.events)
    return baseline, traced, events


def test_round_events_match_returned_logs(traced_grid):
    _, traced, events = traced_grid
    logs = traced[0].result.logs
    rounds = [e for e in events if e["name"] == "engine.round"]
    assert len(rounds) == len(logs)
    for e, log in zip(rounds, logs):
        assert e["round"] == e["t"] == log.round
        assert e["bits_mean"] == pytest.approx(
            float(np.mean(log.bits_per_user)))
        assert e["uplink_s"] == pytest.approx(log.uplink_latency_s)
        assert e["cum_latency_s"] == pytest.approx(log.cum_latency_s)
        assert e["mean_s"] == pytest.approx(log.mean_s)
        if log.test_acc is not None:
            assert e["acc"] == pytest.approx(log.test_acc)
        assert e["scenario"] == "churn-0.7-tiny"
        assert e["quantizer"] == "mixed"
        assert e["power"] == "ours"


def test_jit_round_taps_stream_per_round(traced_grid):
    _, traced, events = traced_grid
    logs = traced[0].result.logs
    taps = [e for e in events if e["name"] == "engine.jit_round"]
    assert len(taps) == len(logs)
    for e, log in zip(taps, logs):
        assert e["kind"] == "jit"
        assert e["round"] == log.round
        # bits stats over ALL users (absent users carry 0 bits)
        assert e["bits_min"] == pytest.approx(
            float(np.min(log.bits_per_user)))
        assert e["bits_median"] == pytest.approx(
            float(np.median(log.bits_per_user)))
        assert e["mean_s"] == pytest.approx(log.mean_s, rel=1e-5)


def test_phy_solve_events_carry_solver_diagnostics(traced_grid):
    _, traced, events = traced_grid
    solves = [e for e in events if e["name"] == "phy.solve"]
    assert len(solves) == len(traced[0].result.logs)
    for e in solves:
        assert e["power"] == "ours"
        assert 0 < e["rate_min"] <= e["rate_median"] <= e["rate_p95"]
        assert e["straggler_s_max"] >= e["straggler_s_min"] > 0
        assert e["bisection_iters_mean"] > 0
        assert 0.0 <= e["bisection_converged_mean"] <= 1.0


def test_phase_scopes_cover_round_lifecycle(traced_grid):
    _, traced, events = traced_grid
    T = len(traced[0].result.logs)
    phases = phase_breakdown(events)
    names = {p["phase"]: p for p in phases}
    for phase in ("train_round", "solve_uplink", "finish_round"):
        assert names[phase]["calls"] == T
        assert names[phase]["total_s"] > 0
    table = per_round_table(events)
    assert [r["round"] for r in table] == list(range(1, T + 1))
    assert all("train_s" in r and "bisect_iters" in r for r in table)


def test_obs_session_does_not_perturb_results(traced_grid):
    """Round outputs are bit-identical with and without a session."""
    baseline, traced, _ = traced_grid
    for rb, rt in zip(baseline, traced):
        lb, lt = rb.result.logs, rt.result.logs
        assert len(lb) == len(lt)
        for a, b in zip(lb, lt):
            np.testing.assert_array_equal(a.bits_per_user,
                                          b.bits_per_user)
            assert a.test_acc == b.test_acc
            assert a.mean_s == b.mean_s
            assert a.uplink_latency_s == b.uplink_latency_s
        assert rb.summary == rt.summary


# ------------------------------------------------ solver info growth
def test_solver_info_exposes_convergence_state():
    from repro.core.channel import CFmMIMOConfig, make_channel
    from repro.phy import (bisection_solve, bundle_from_realizations,
                           dinkelbach_solve, maxsum_solve)

    chan = make_channel(CFmMIMOConfig(M=8, N=2, K=4), seed=0)
    cb = bundle_from_realizations([chan])
    bits = np.full((1, 4), 1e6)

    sol = bisection_solve(cb, bits)
    assert bool(np.all(sol.info["bisection_converged"]))
    assert float(np.max(sol.info["bisection_gap"])) >= 0.0

    sol = dinkelbach_solve(cb, bits, outer=6)
    assert set(sol.info) >= {"dinkelbach_converged",
                             "dinkelbach_residual",
                             "dinkelbach_safeguard"}
    assert np.all(np.asarray(sol.info["dinkelbach_residual"]) >= 0.0)
    assert np.all(np.asarray(sol.info["dinkelbach_safeguard"]) >= 0.0)

    sol = maxsum_solve(cb, bits, iters=20)
    assert np.asarray(sol.info["maxsum_iters"]).item() == 20.0
    assert np.isfinite(float(np.max(sol.info["maxsum_grad_norm"])))


# -------------------------------------------------- report rendering
def test_report_renders_wire_and_csv(tmp_path):
    scn = _tiny("fused-wire", T=2)
    path = str(tmp_path / "wire.jsonl")
    with obs.session(jsonl=path):
        run_grid_batched([scn],
                         {"mixed": ("mixed-resolution",
                                    {"lambda_": 0.2, "b": 10})},
                         POWERS, quick=False)
    events = load_events(path)
    wire = wire_summary(events)
    assert wire["encode_bytes_out"] == wire["decode_bytes_in"] > 0
    assert wire["compression_ratio"] > 1.0
    assert 0 < wire["roofline_fraction"] < 1.0

    csv_out = str(tmp_path / "rounds.csv")
    text = render_report(events, csv_out=csv_out)
    for section in ("== per-round ==", "== phase time ==",
                    "== fused wire traffic ==", "== recompilations =="):
        assert section in text
    header = open(csv_out).readline()
    assert header.startswith("round,")


# ------------------------------------- engine verbose / log_every knob
def test_engine_round_print_behind_verbose(capsys):
    from repro.sim.sweep import run_cell

    scn = _tiny("paper-table3", T=2)
    run_cell(scn, ("mixed-resolution", {"lambda_": 0.2, "b": 4}),
             quick=False)
    assert "[round" not in capsys.readouterr().out   # default: silent
    run_cell(scn, ("mixed-resolution", {"lambda_": 0.2, "b": 4}),
             quick=False, verbose=True)
    assert "[round" in capsys.readouterr().out       # quickstart line


def test_engine_log_every_throttles_console(capsys):
    from repro.sim.engine import EngineConfig
    from repro.sim.scenarios import build_problem
    from repro.sim.sweep import _make_engine

    scn = _tiny("paper-table3", T=4)
    engine = _make_engine(scn, build_problem(scn),
                          ("mixed-resolution", {"lambda_": 0.2, "b": 4}),
                          None)
    engine.engine_cfg = dataclasses.replace(
        engine.engine_cfg, verbose=True, log_every=2)
    engine.run()
    out = capsys.readouterr().out
    printed = [ln for ln in out.splitlines() if ln.startswith("[round")]
    # eval_every=1 on the tiny scenario: rounds 2 and 4 (t==T) print
    assert len(printed) == 2
    assert "[round    2]" in out and "[round    4]" in out
