"""Per-layer budget (DESIGN.md §13) + the flatten/shard bugfix battery.

Pins the PR's contracts: mixed-dtype pytrees round-trip through
flatten/unflatten; LayerBudget segment offsets index the SAME flat
order the engine concatenates; LayerBudget.uniform() is bit-for-bit
the global-budget path; per-user payload bits equal the sum of the
per-segment bits exactly; empty shards and oversized packed planes
fail loudly everywhere.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import PaperCNNConfig
from repro.core.quantize import (LayerBudget, MixedResolutionQuantizer,
                                 mixed_resolution_quantize,
                                 resolve_segments, segmented_quantize,
                                 validate_segments)
from repro.core.quantize.base import flatten_pytree, unflatten_pytree
from repro.core.quantize.layer_budget import BudgetRule, classify_leaf
from repro.data import make_image_classification, partition_iid
from repro.data.federated import partition_powerlaw, validate_shards
from repro.fl import FLConfig, run_fl
from repro.kernels import (PACKED_DIM_LIMIT, WirePath, check_packed_dim,
                           segmented_wire_aggregate)
from repro.kernels.ops import mixed_res_encode
from repro.sim import EngineConfig, VectorizedFLEngine

jax.config.update("jax_enable_x64", False)


# ------------------------------------------------ satellite 1: dtypes
def test_flatten_pytree_mixed_dtype_roundtrip():
    tree = {"w": jnp.ones((3, 4), jnp.bfloat16),
            "g": jnp.arange(5, dtype=jnp.float16),
            "b": jnp.linspace(0, 1, 7, dtype=jnp.float32)}
    flat, spec = flatten_pytree(tree)
    assert flat.dtype == jnp.float32
    back = unflatten_pytree(flat, spec)
    for k in tree:
        assert back[k].dtype == tree[k].dtype, k
        np.testing.assert_array_equal(
            np.asarray(back[k], np.float32), np.asarray(tree[k], np.float32))


def test_unflatten_pytree_legacy_3tuple_spec():
    tree = {"a": jnp.ones((2, 2), jnp.bfloat16)}
    flat, spec = flatten_pytree(tree)
    legacy = spec[:3]                      # pre-dtype stored spec
    back = unflatten_pytree(flat, legacy)
    assert back["a"].dtype == flat.dtype   # old behaviour preserved
    np.testing.assert_array_equal(np.asarray(back["a"]), np.ones((2, 2)))


def test_flatten_leaf_order_matches_tree_flatten():
    """Segment offsets index flatten_pytree's vector: the with-path
    walk (resolve_segments) and the plain flatten must agree on leaf
    order, including nesting."""
    tree = {"z": {"inner": jnp.full((2, 3), 2.0)},
            "a": jnp.full((4,), 1.0),
            "m": [jnp.full((2, 2), 3.0), jnp.full((3,), 4.0)]}
    flat, _ = flatten_pytree(tree)
    segs = resolve_segments(tree, LayerBudget.uniform(), 0.2, 10)
    validate_segments(segs, int(flat.size))
    leaves_wp, _ = jax.tree_util.tree_flatten_with_path(tree)
    plain = jax.tree_util.tree_flatten(tree)[0]
    for (path, leaf), leaf2 in zip(leaves_wp, plain):
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(leaf2))
    # offsets really slice the right leaves: reconstruct first leaf
    np.testing.assert_array_equal(np.asarray(flat[:4]), np.full((4,), 1.0))
    # and the engine's stacked-delta concat idiom (tree_flatten +
    # reshape(U, -1) + concat) lays out each row exactly like
    # flatten_pytree — the order the budget segments index
    U = 2
    stacked = jax.tree_util.tree_map(
        lambda l: jnp.stack([l, 2.0 * l]), tree)
    rows = jnp.concatenate(
        [jnp.reshape(l, (U, -1)).astype(jnp.float32)
         for l in jax.tree_util.tree_leaves(stacked)], axis=1)
    np.testing.assert_array_equal(np.asarray(rows[0]), np.asarray(flat))
    np.testing.assert_array_equal(np.asarray(rows[1]),
                                  2.0 * np.asarray(flat))


# ------------------------------------------------ LayerBudget surface
def test_layer_budget_api_validation():
    with pytest.raises(ValueError, match="duplicate"):
        LayerBudget(rules=(BudgetRule("norm"), BudgetRule("norm")))
    with pytest.raises(ValueError, match="unknown budget group"):
        BudgetRule("attention")
    with pytest.raises(ValueError, match="lambda_"):
        BudgetRule("norm", lambda_=1.5)
    with pytest.raises(ValueError, match="b must be"):
        BudgetRule("norm", b=1)
    assert LayerBudget.uniform().is_uniform
    lb = LayerBudget.by_group(norm=(0.1, 12), default=(0.3, 6))
    assert not lb.is_uniform
    assert lb.rule_for("norm").b == 12
    assert lb.rule_for("matmul").b == 6          # default fallback
    assert hash(lb) == hash(LayerBudget.by_group(
        norm=(0.1, 12), default=(0.3, 6)))       # hashable for WirePath


def test_classify_leaf_groups():
    tree = {"embed_tokens": jnp.ones((8, 4)), "ln": jnp.ones((4,)),
            "w": jnp.ones((4, 4))}
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    got = {jax.tree_util.keystr(p).strip("[]'\""): classify_leaf(p, l)
           for p, l in leaves}
    assert got == {"embed_tokens": "embed", "ln": "norm", "w": "matmul"}
    # stacked mode: a leading replica axis must not promote a norm gain
    (p, l) = jax.tree_util.tree_flatten_with_path(
        {"ln": jnp.ones((4, 16))})[0][0]
    assert classify_leaf(p, l) == "matmul"
    assert classify_leaf(p, l, skip_leading=1) == "norm"


def test_wirepath_budget_validation():
    lb = LayerBudget.by_group(norm=(0.1, 12))
    WirePath(plane="packed", budget=lb).validate()
    WirePath(plane="signplane", budget=LayerBudget.uniform()).validate()
    with pytest.raises(ValueError):
        WirePath(plane="packed", budget=object()).validate()
    with pytest.raises(ValueError, match="signplane"):
        WirePath(plane="signplane", budget=lb).validate()
    with pytest.raises(ValueError, match="cohort|stream"):
        WirePath(plane="packed", cohort_size=2, budget=lb).validate()
    assert WirePath(plane="packed", budget=lb).effective_budget is lb
    assert WirePath(plane="packed",
                    budget=LayerBudget.uniform()).effective_budget is None
    assert WirePath(plane="packed").effective_budget is None


# ------------------------------------- bits-sum identity + references
def _toy_segments_and_flat(U=3, seed=0):
    tree = {"embed": jnp.ones((6, 8)), "ln": jnp.ones((8,)),
            "w": jnp.ones((8, 8))}
    lb = LayerBudget.by_group(embed=(0.4, 4), norm=(0.05, 12),
                              matmul=(0.2, 8))
    segs = lb.segments_for(tree, 0.2, 10)
    d = sum(s.size for s in segs)
    flat = jax.random.normal(jax.random.PRNGKey(seed), (U, d))
    return segs, flat


def test_segmented_bits_sum_identity():
    """Per-user payload bits under a budget == exact sum of per-segment
    payloads, and each segment's payload equals the eager global
    quantizer run on that slice alone."""
    segs, flat = _toy_segments_and_flat()
    recon, bits, aux = segmented_quantize(flat, segs)
    np.testing.assert_array_equal(np.asarray(bits),
                                  np.asarray(aux["segment_bits"]).sum(1))
    for j, seg in enumerate(segs):
        for u in range(flat.shape[0]):
            ref = mixed_resolution_quantize(
                flat[u, seg.start:seg.stop], seg.lambda_, seg.b)
            assert float(ref.bits) == float(aux["segment_bits"][u, j])
            np.testing.assert_array_equal(
                np.asarray(ref.recon),
                np.asarray(recon[u, seg.start:seg.stop]))


def test_segmented_wire_matches_dense_segments():
    """The packed-plane segmented aggregate reproduces the dense
    per-segment quantize + weighted mean (same contract the global
    wire kernels pin against mixed_resolution_quantize)."""
    segs, flat = _toy_segments_and_flat(U=4, seed=1)
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4])
    agg, bits, aux = segmented_wire_aggregate(flat, w, segs)
    recon, bits_d, aux_d = segmented_quantize(flat, segs)
    ref = jnp.einsum("k,kd->d", w, recon)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(bits_d))
    np.testing.assert_array_equal(np.asarray(aux["segment_bits"]),
                                  np.asarray(aux_d["segment_bits"]))


# --------------------------------------------- engine parity contract
@pytest.fixture(scope="module")
def cnn_problem():
    full = make_image_classification(n_samples=160, hw=8, n_classes=2,
                                     noise=0.25, seed=0)
    train = dataclasses.replace(full, x=full.x[:128], y=full.y[:128])
    test = dataclasses.replace(full, x=full.x[128:], y=full.y[128:])
    cfg = PaperCNNConfig(input_hw=8, n_classes=2, channels=3,
                         conv_filters=4, dense_units=16)
    shards = partition_iid(train, 4)
    fl = FLConfig(L=1, T=2, batch_size=16, alpha=0.02, eval_every=1,
                  seed=0)
    return train, test, shards, cfg, fl


def _run(problem, wire):
    train, test, shards, cfg, fl = problem
    q = MixedResolutionQuantizer(lambda_=0.2, b=10)
    eng = VectorizedFLEngine(
        train, test, shards, cfg, q, None, None, fl,
        engine=EngineConfig(wire=wire, fused=True))
    return eng.run()


@pytest.mark.parametrize("plane", ["packed", "dense"])
def test_uniform_budget_bit_for_bit(cnn_problem, plane):
    """LayerBudget.uniform() must reproduce budget=None exactly —
    same compiled graph, same bits, same params."""
    r0 = _run(cnn_problem, WirePath(plane=plane))
    r1 = _run(cnn_problem,
              WirePath(plane=plane, budget=LayerBudget.uniform()))
    assert len(r0.logs) == len(r1.logs)
    for a, b in zip(r0.logs, r1.logs):
        np.testing.assert_array_equal(a.bits_per_user, b.bits_per_user)
        assert a.test_acc == b.test_acc
    for x, y in zip(jax.tree_util.tree_leaves(r0.params),
                    jax.tree_util.tree_leaves(r1.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("plane", ["packed", "dense"])
def test_engine_budget_bits_sum(cnn_problem, plane):
    """A non-uniform budget runs end-to-end and its logged per-user
    bits equal the static per-segment payload sum."""
    train, test, shards, cfg, fl = cnn_problem
    lb = LayerBudget.by_group(norm=(0.05, 12), matmul=(0.3, 6))
    q = MixedResolutionQuantizer(lambda_=0.2, b=10)
    eng = VectorizedFLEngine(
        train, test, shards, cfg, q, None, None, fl,
        engine=EngineConfig(wire=WirePath(plane=plane, budget=lb),
                            fused=True))
    assert eng._segments is not None
    validate_segments(eng._segments, eng.d)
    res = eng.run()
    assert np.isfinite(np.asarray(res.logs[-1].bits_per_user)).all()
    # budgets change the payload vs the global run
    r0 = _run(cnn_problem, WirePath(plane=plane))
    assert not np.array_equal(np.asarray(res.logs[0].bits_per_user),
                              np.asarray(r0.logs[0].bits_per_user))


def test_engine_budget_mode_restrictions(cnn_problem):
    train, test, shards, cfg, fl = cnn_problem
    lb = LayerBudget.by_group(norm=(0.05, 12))
    from repro.core.quantize import ClassicQuantizer
    with pytest.raises(ValueError, match="mixed-resolution"):
        VectorizedFLEngine(
            train, test, shards, cfg, ClassicQuantizer(), None, None, fl,
            engine=EngineConfig(wire=WirePath(plane="dense", budget=lb),
                                fused=True))
    with pytest.raises(ValueError, match="fused"):
        VectorizedFLEngine(
            train, test, shards, cfg,
            MixedResolutionQuantizer(lambda_=0.2, b=10), None, None, fl,
            engine=EngineConfig(wire=WirePath(plane="dense", budget=lb),
                                fused=False))


# ------------------------------------- satellite 2: shard guarantees
def test_validate_shards_rejects_empty():
    ds = make_image_classification(n_samples=16, hw=8, n_classes=2, seed=0)
    shards = partition_iid(ds, 4)
    validate_shards(shards)
    shards[1] = np.array([], dtype=np.int64)
    with pytest.raises(ValueError, match="empty data shard"):
        validate_shards(shards)


def test_run_fl_rejects_empty_shard():
    full = make_image_classification(n_samples=40, hw=8, n_classes=2,
                                     seed=0)
    train = dataclasses.replace(full, x=full.x[:32], y=full.y[:32])
    test = dataclasses.replace(full, x=full.x[32:], y=full.y[32:])
    cfg = PaperCNNConfig(input_hw=8, n_classes=2, channels=3,
                         conv_filters=4, dense_units=8)
    shards = partition_iid(train, 4)
    shards[2] = np.array([], dtype=np.int64)
    fl = FLConfig(L=1, T=1, batch_size=4, seed=0)
    with pytest.raises(ValueError, match="empty data shard"):
        run_fl(train, test, shards, cfg,
               MixedResolutionQuantizer(lambda_=0.2, b=10), None, None, fl)


def test_partition_powerlaw_min_one_sample():
    ds = make_image_classification(n_samples=20, hw=8, n_classes=2, seed=0)
    for seed in range(5):
        shards = partition_powerlaw(ds, K=10, exponent=2.5, seed=seed)
        assert min(len(s) for s in shards) >= 1
        validate_shards(shards)
    with pytest.raises(ValueError, match=">= 1 sample per user"):
        partition_powerlaw(ds, K=40, exponent=1.5, seed=0)


# --------------------------------------- satellite 3: 2**24 guard
def test_check_packed_dim_guard():
    check_packed_dim(PACKED_DIM_LIMIT - 1)
    with pytest.raises(ValueError, match="2\\*\\*24|16777216"):
        check_packed_dim(PACKED_DIM_LIMIT)
    # encoder path fails at trace time — eval_shape never allocates
    big = jax.ShapeDtypeStruct((2, PACKED_DIM_LIMIT), jnp.float32)
    with pytest.raises(ValueError, match="mixed_res_encode"):
        jax.eval_shape(lambda x: mixed_res_encode(x, 0.2, 10), big)


def test_dist_packed_dim_guard():
    from repro.dist import CompressorConfig, aggregate_flat_stacked
    comp = CompressorConfig(kind="mixed", s_budget=0.01, bits=8,
                            wire=WirePath(plane="packed"))
    big = jax.ShapeDtypeStruct((2, PACKED_DIM_LIMIT), jnp.float32)
    with pytest.raises(ValueError, match="packed dist exchange"):
        jax.eval_shape(lambda x: aggregate_flat_stacked(x, comp), big)


# --------------------------------------------------- dist budget path
def test_dist_budget_segments_and_parity():
    from repro.dist import CompressorConfig, aggregate_delta
    deltas = {"ln": jax.random.normal(jax.random.PRNGKey(0), (4, 16)),
              "w": jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8))}
    lb = LayerBudget.by_group(norm=(0.0, 16, 0.5), matmul=(0.0, 8))
    comp = CompressorConfig(kind="mixed", s_budget=0.25, bits=8,
                            wire=WirePath(plane="packed", budget=lb))
    agg, info = aggregate_delta(deltas, None, (), comp)
    assert len(info["segments"]) == 2
    assert info["segments"][0].group == "norm"       # stacked-rank fix
    assert sum(info["segment_bits"]) == info["wire_bits_per_replica"]
    # uniform budget == no budget, bit for bit
    aggU, infoU = aggregate_delta(
        deltas, None, (), dataclasses.replace(
            comp, wire=WirePath(plane="packed",
                                budget=LayerBudget.uniform())))
    agg0, info0 = aggregate_delta(
        deltas, None, (), dataclasses.replace(
            comp, wire=WirePath(plane="packed")))
    assert infoU["wire_bits_per_replica"] == info0["wire_bits_per_replica"]
    for a, b in zip(jax.tree_util.tree_leaves(aggU),
                    jax.tree_util.tree_leaves(agg0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dist_budget_validation():
    from repro.dist import CompressorConfig
    lb12 = LayerBudget.by_group(norm=(0.0, 12))
    with pytest.raises(ValueError, match="divide"):
        CompressorConfig(kind="mixed", s_budget=0.25, bits=8,
                         wire=WirePath(plane="packed",
                                       budget=lb12)).validate()
    with pytest.raises(ValueError, match="ring"):
        CompressorConfig(
            kind="mixed", s_budget=0.25, bits=8,
            wire=WirePath(plane="packed", reduce="ring",
                          budget=LayerBudget.by_group(
                              norm=(0.0, 16)))).validate()
