"""Equivalence tests for the chunked sequence-mixing formulations:
the TPU-friendly chunked algorithms must match step-by-step oracles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models.config import ModelConfig
from repro.models.rwkv import _wkv_chunked, rwkv_time_naive
from repro.models.ssm import (_ssd_chunked, init_mamba, init_mamba_state,
                              mamba_apply)
from repro.models.moe import _local_dispatch, _local_combine


# ------------------------------------------------------------ RWKV6 WKV
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10 ** 6), st.sampled_from([32, 64, 128]))
def test_wkv_chunked_matches_naive(seed, S):
    B, H, K = 2, 3, 8
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.standard_normal((B, S, H, K)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, K)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, K)), jnp.float32)
    # decays in (0, 1): logw < 0, include fast-forget extremes
    logw = jnp.asarray(-np.exp(rng.uniform(-3, 1.5, (B, S, H, K))),
                       jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, K)) * 0.2, jnp.float32)

    cfg = get_config("rwkv6-7b").reduced()
    y_c, S_c = _wkv_chunked(r, k, v, logw, u, None, cfg)
    y_n, S_n = rwkv_time_naive(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S_n),
                               rtol=2e-4, atol=2e-4)


def test_wkv_chunked_carries_state():
    """Two chunked halves with carried state == one full pass."""
    B, S, H, K = 1, 64, 2, 8
    rng = np.random.default_rng(0)
    mk = lambda: jnp.asarray(rng.standard_normal((B, S, H, K)), jnp.float32)
    r, k, v = mk(), mk(), mk()
    logw = -jnp.exp(jnp.asarray(rng.uniform(-2, 0.5, (B, S, H, K)),
                                jnp.float32))
    u = jnp.zeros((H, K), jnp.float32)
    cfg = get_config("rwkv6-7b").reduced()
    y_full, S_full = _wkv_chunked(r, k, v, logw, u, None, cfg)
    h = S // 2
    y1, S1 = _wkv_chunked(r[:, :h], k[:, :h], v[:, :h], logw[:, :h], u,
                          None, cfg)
    y2, S2 = _wkv_chunked(r[:, h:], k[:, h:], v[:, h:], logw[:, h:], u,
                          S1, cfg)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ Mamba2 SSD
def _ssd_naive(xh, Bf, Cf, dt, log_dec):
    """Per-step recurrence oracle: h_t = e^{dt a} h + dt x (x) B."""
    B, S, H, P = xh.shape
    N = Bf.shape[-1]
    h = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        h = (h * jnp.exp(log_dec[:, t])[..., None, None]
             + jnp.einsum("bh,bhp,bn->bhpn", dt[:, t], xh[:, t], Bf[:, t]))
        ys.append(jnp.einsum("bhpn,bn->bhp", h, Cf[:, t]))
    return jnp.stack(ys, 1), h


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (48, 16)])
def test_ssd_chunked_matches_naive(S, chunk):
    B, H, P, N = 2, 3, 4, 5
    rng = np.random.default_rng(S)
    xh = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    Bf = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cf = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 1.0, (B, S, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 8.0, (H,)), jnp.float32)
    log_dec = dt * a
    cfg = dataclasses.replace(get_config("zamba2-7b").reduced(),
                              ssm_chunk=chunk)
    y_c, h_c = _ssd_chunked(xh, Bf, Cf, dt, log_dec, cfg)
    y_n, h_n = _ssd_naive(xh, Bf, Cf, dt, log_dec)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_n),
                               rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_chunked():
    """Step-by-step decode through mamba_apply == one chunked pass."""
    cfg = dataclasses.replace(get_config("zamba2-7b").reduced(),
                              ssm_chunk=8)
    params = init_mamba(jax.random.PRNGKey(0), cfg)
    B, S = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32) * 0.5
    y_full, _ = mamba_apply(params, x, cfg, state=None)
    state = init_mamba_state(cfg, B)
    outs = []
    for t in range(S):
        y, state = mamba_apply(params, x[:, t:t + 1], cfg, state=state)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=5e-2, atol=5e-2)


# ------------------------------------------------------------ MoE dispatch
def test_moe_dispatch_matches_dense():
    """Capacity dispatch+combine == dense weighted expert sum when no
    tokens are dropped."""
    rng = np.random.default_rng(0)
    T, d, E, k, C = 16, 8, 4, 2, 16       # capacity ample: no drops
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    top_idx = jnp.asarray(rng.integers(0, E, (T, k)), jnp.int32)
    # ensure distinct experts per token
    top_idx = top_idx.at[:, 1].set((top_idx[:, 0] + 1) % E)
    top_w = jnp.asarray(rng.uniform(0.2, 1.0, (T, k)), jnp.float32)

    buf, info = _local_dispatch(x, top_idx, top_w, E, C)
    # identity "experts": y = x  -> combine == sum_k w * x
    y = _local_combine(buf, info, T, d)
    expect = (top_w.sum(-1)[:, None] * x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_moe_dispatch_drops_over_capacity():
    T, d, E, k, C = 8, 4, 2, 1, 2          # 8 tokens -> 2 experts, cap 2
    x = jnp.ones((T, d), jnp.float32)
    top_idx = jnp.zeros((T, k), jnp.int32)  # everyone wants expert 0
    top_w = jnp.ones((T, k), jnp.float32)
    buf, info = _local_dispatch(x, top_idx, top_w, E, C)
    # only C tokens fit
    assert float(jnp.sum(buf)) == C * d
    y = _local_combine(buf, info, T, d)
    assert float(jnp.sum(y)) == C * d       # dropped tokens get zeros
