"""Streaming cohort aggregation + WirePath API (PR 8).

Pins the contracts the cohort refactor promises:

* cohort_size=None is THE vectorized wire path — and cohort scans of
  any size C (1, K, K%C != 0) reproduce it bit-for-bit (DESIGN.md §12:
  the chunked packed accumulate is a left fold in the same order);
* churn masks that straddle a cohort boundary behave identically to
  the vectorized step (absent users fold exact zeros);
* the replicated Monte-Carlo axis composes with cohort streaming;
* the two-level AP-cluster hierarchy matches the flat fan-in to
  float32 roundoff (partials reassociate the sum — documented);
* no [K, d] buffer exists anywhere in the traced cohort step (the
  memory contract that lets K reach 10^4-10^5), asserted by walking
  the jaxpr;
* the legacy knobs (EngineConfig.aggregation, CompressorConfig
  .wire_path, solve_uplink_host_detailed) keep working through
  DeprecationWarning shims.
"""
import dataclasses
import os
import time
import warnings

import jax
import numpy as np
import pytest

from repro.configs.paper_cnn import PaperCNNConfig
from repro.core.quantize import MixedResolutionQuantizer
from repro.data import make_image_classification, partition_iid
from repro.dist import CompressorConfig
from repro.fl import FLConfig
from repro.kernels import WirePath, from_aggregation, from_wire_path
from repro.sim import (EngineConfig, StalenessConfig, UplinkSolution,
                       VectorizedFLEngine, get_scenario)

K = 7          # deliberately prime: K % C != 0 for every C in 2..6
COHORTS = [1, 3, K]   # one-user cohorts, uneven split (7 % 3 != 0), C=K


@pytest.fixture(scope="module")
def problem():
    full = make_image_classification(n_samples=360, hw=8, n_classes=3,
                                     noise=0.25, seed=0)
    train = dataclasses.replace(full, x=full.x[:280], y=full.y[:280])
    test = dataclasses.replace(full, x=full.x[280:], y=full.y[280:])
    cfg = PaperCNNConfig(input_hw=8, n_classes=3)
    return train, test, cfg


def _leaves(params):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(params)]


def _engine(problem, wire, participation=1.0, T=3):
    train, test, cfg = problem
    shards = partition_iid(train, K)
    fl = FLConfig(L=2, T=T, batch_size=8, alpha=0.02, eval_every=1,
                  seed=0)
    q = MixedResolutionQuantizer(lambda_=0.2, b=10)
    return VectorizedFLEngine(
        train, test, shards, cfg, q, None, None, fl,
        engine=EngineConfig(wire=wire, participation=participation))


@pytest.fixture(scope="module")
def wire_baseline(problem):
    """The vectorized packed-wire run every cohort slicing must hit."""
    return _engine(problem, WirePath(plane="packed")).run()


# -------------------------------------------------- cohort == vectorized
@pytest.mark.parametrize("C", COHORTS)
def test_cohort_scan_matches_vectorized_bit_for_bit(problem,
                                                    wire_baseline, C):
    """Any cohort slicing — one user at a time, uneven K % C != 0,
    one cohort of all K — reproduces cohort_size=None bit-for-bit on
    payload bits, accuracy and every parameter."""
    res = _engine(problem,
                  WirePath(plane="packed", cohort_size=C)).run()
    for lb, lc in zip(wire_baseline.logs, res.logs):
        np.testing.assert_array_equal(lb.bits_per_user, lc.bits_per_user)
        assert lb.mean_s == lc.mean_s
        assert lb.test_acc == lc.test_acc
    for a, b in zip(_leaves(wire_baseline.params), _leaves(res.params)):
        np.testing.assert_array_equal(a, b)


def test_churn_straddling_cohort_boundary_bit_for_bit(problem):
    """Partial participation draws masks on the K axis with no regard
    for cohort boundaries; a churned user inside a cohort folds an
    exact zero (weight 0 -> +-0.0 contribution), so the streamed run
    still matches the vectorized one bit-for-bit."""
    vec = _engine(problem, WirePath(plane="packed"),
                  participation=0.5, T=4).run()
    coh = _engine(problem, WirePath(plane="packed", cohort_size=3),
                  participation=0.5, T=4).run()
    saw_partial = False
    for lv, lc in zip(vec.logs, coh.logs):
        np.testing.assert_array_equal(lv.bits_per_user, lc.bits_per_user)
        assert lv.test_acc == lc.test_acc
        # the seeded mask must actually split users across the 3|3|1
        # cohort boundaries (some active, some churned)
        n_active = int(np.count_nonzero(lv.bits_per_user))
        saw_partial |= 0 < n_active < K
    assert saw_partial, "participation=0.5 never churned anyone"
    for a, b in zip(_leaves(vec.params), _leaves(coh.params)):
        np.testing.assert_array_equal(a, b)


def test_replicated_axis_composes_with_cohorts(problem):
    """The Monte-Carlo replicate axis (lax.map over the fused step)
    runs the cohort scan per replicate and matches the vectorized
    replicated run bit-for-bit."""
    R, T = 2, 2
    runs = []
    for wire in (WirePath(plane="packed"),
                 WirePath(plane="packed", cohort_size=3)):
        eng = _engine(problem, wire, T=T)
        state = eng.start_replicated_run(R)
        works = [eng.train_round_replicated(state, t)
                 for t in range(1, T + 1)]
        runs.append((works, jax.device_get(state.params)))
    (w_vec, p_vec), (w_coh, p_coh) = runs
    for wv, wc in zip(w_vec, w_coh):
        np.testing.assert_array_equal(wv.bits_np, wc.bits_np)
        np.testing.assert_array_equal(wv.active, wc.active)
    for a, b in zip(_leaves(p_vec), _leaves(p_coh)):
        np.testing.assert_array_equal(a, b)


def test_cluster_hierarchy_matches_flat_to_roundoff(problem):
    """clusters=2 splits the K users into two on-device partial [d]
    aggregates combined host-side; the partials reassociate the outer
    sum, so the match is float32 roundoff, not bit-for-bit (DESIGN.md
    §12).  Payload bits are per-user header stats — those stay exact."""
    flat = _engine(problem,
                   WirePath(plane="packed", cohort_size=3)).run()
    hier = _engine(problem,
                   WirePath(plane="packed", cohort_size=3,
                            clusters=2)).run()
    for lf, lh in zip(flat.logs, hier.logs):
        np.testing.assert_array_equal(lf.bits_per_user, lh.bits_per_user)
    for a, b in zip(_leaves(flat.params), _leaves(hier.params)):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


def test_cluster_hierarchy_rejects_replicated_mode(problem):
    eng = _engine(problem, WirePath(plane="packed", cohort_size=3,
                                    clusters=2))
    with pytest.raises(ValueError, match="replicated"):
        eng.start_replicated_run(2)


def test_cohort_scenarios_registered():
    for name in ("cohort-wire", "cohort-hierarchy"):
        scn = get_scenario(name)
        ecfg = scn.engine_config()
        assert ecfg.wire is not None and ecfg.wire.streaming
    assert get_scenario("cohort-hierarchy").clusters > 1


# ------------------------------------------------- the memory contract
def _walk_avals(jaxpr, out):
    """Every aval in a jaxpr, recursing into sub-jaxprs (scan/cond/
    pjit bodies) through eqn params."""
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(aval)
        for val in eqn.params.values():
            _walk_sub(val, out)
    return out


def _walk_sub(val, out):
    if hasattr(val, "eqns"):                      # Jaxpr
        _walk_avals(val, out)
    elif hasattr(val, "jaxpr"):                   # ClosedJaxpr
        _walk_avals(val.jaxpr, out)
    elif isinstance(val, (tuple, list)):
        for v in val:
            _walk_sub(v, out)


def _trace_step_avals(eng):
    """Trace the engine's fused step abstractly and return every
    intermediate aval (nothing executes)."""
    sel = np.zeros((eng.K, eng.fl.L, eng.take), dtype=np.int64)
    sds = lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
    xs = sds(eng.dataset.x[sel])
    ys = sds(eng.dataset.y[sel])
    w = jax.ShapeDtypeStruct((eng.K,), np.float32)
    closed = jax.make_jaxpr(eng._fused_step_fn)(
        jax.tree_util.tree_map(sds, eng.params),
        jax.tree_util.tree_map(sds, eng.qstate), xs, ys, w, w)
    return _walk_avals(closed.jaxpr, [])


def _dense_user_buffers(avals, K, d):
    """Avals carrying BOTH the user axis and the model dimension —
    the [*, K, *, d, *] buffers cohort streaming must never create."""
    return [a for a in avals if K in a.shape and d in a.shape]


def test_cohort_step_never_materializes_K_by_d(problem):
    """Walk the traced cohort step's jaxpr (including the scan body):
    no intermediate may carry the user axis and the model dimension
    together.  The vectorized step does (sanity: the detector sees
    its [K, d] flat-delta buffer), the cohort scan must not."""
    vec = _engine(problem, WirePath(plane="packed"))
    coh = _engine(problem, WirePath(plane="packed", cohort_size=3))
    d = vec.d
    assert K != d and d not in (8, 3)   # dims unambiguous in shapes
    assert _dense_user_buffers(_trace_step_avals(vec), K, d), \
        "detector sanity: the vectorized step must show a [K, d] buffer"
    offenders = _dense_user_buffers(_trace_step_avals(coh), K, d)
    assert not offenders, [a.shape for a in offenders]


# ------------------------------------------------------ WirePath rules
def test_wirepath_validation_errors():
    with pytest.raises(ValueError, match="plane"):
        WirePath(plane="sparse")
    with pytest.raises(ValueError, match="lowering"):
        WirePath(lowering="jit")
    with pytest.raises(ValueError, match="reduce"):
        WirePath(reduce="tree")
    with pytest.raises(ValueError, match="packed"):
        WirePath(plane="dense", cohort_size=4)
    with pytest.raises(ValueError, match="cohort_size"):
        WirePath(plane="packed", clusters=2)
    with pytest.raises(ValueError, match="cohort_size"):
        WirePath(plane="packed", cohort_size=0)


def test_engine_rejects_wire_plus_legacy_aggregation(problem):
    train, test, cfg = problem
    shards = partition_iid(train, K)
    fl = FLConfig(L=1, T=1, batch_size=8, seed=0)
    with pytest.raises(ValueError, match="not both"):
        VectorizedFLEngine(
            train, test, shards, cfg,
            MixedResolutionQuantizer(0.2, 10), None, None, fl,
            engine=EngineConfig(wire=WirePath(plane="packed"),
                                aggregation="signplane"))


def test_async_rejects_cohort_streaming(problem):
    train, test, cfg = problem
    shards = partition_iid(train, K)
    fl = FLConfig(L=1, T=1, batch_size=8, seed=0)
    with pytest.raises(ValueError, match="lockstep"):
        VectorizedFLEngine(
            train, test, shards, cfg,
            MixedResolutionQuantizer(0.2, 10), None, None, fl,
            engine=EngineConfig(
                wire=WirePath(plane="packed", cohort_size=3),
                async_mode=True,
                staleness=StalenessConfig(deadline_s=1.0)))


# ------------------------------------------------- deprecation shims
def test_legacy_aggregation_string_warns_and_matches(problem,
                                                     wire_baseline):
    """EngineConfig(aggregation="wire") still runs — through the shim,
    with a DeprecationWarning, bit-for-bit with the WirePath spec."""
    train, test, cfg = problem
    shards = partition_iid(train, K)
    fl = FLConfig(L=2, T=3, batch_size=8, alpha=0.02, eval_every=1,
                  seed=0)
    with pytest.warns(DeprecationWarning, match="aggregation"):
        eng = VectorizedFLEngine(
            train, test, shards, cfg,
            MixedResolutionQuantizer(0.2, 10), None, None, fl,
            engine=EngineConfig(aggregation="wire"))
    res = eng.run()
    for lb, lc in zip(wire_baseline.logs, res.logs):
        np.testing.assert_array_equal(lb.bits_per_user, lc.bits_per_user)
    for a, b in zip(_leaves(wire_baseline.params), _leaves(res.params)):
        np.testing.assert_array_equal(a, b)


def test_legacy_shim_functions_warn():
    with pytest.warns(DeprecationWarning, match="aggregation"):
        assert from_aggregation("wire").plane == "packed"
    with pytest.warns(DeprecationWarning, match="wire_path"):
        assert from_wire_path("fused").plane == "packed"
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # warn=False is silent
        assert from_aggregation("signplane", warn=False).plane \
            == "signplane"
        assert from_wire_path("reference", warn=False).plane \
            == "signplane"
    with pytest.raises(ValueError, match="aggregation"):
        from_aggregation("sparse")


def test_compressor_wire_path_shim():
    comp = CompressorConfig("mixed", s_budget=0.25, bits=4,
                            wire_path="fused")
    with pytest.warns(DeprecationWarning, match="wire_path"):
        assert comp.resolved_wire().plane == "packed"
    both = CompressorConfig("mixed", s_budget=0.25, bits=4,
                            wire_path="fused",
                            wire=WirePath(plane="packed"))
    with pytest.raises(ValueError, match="not both"):
        both.resolved_wire()
    # default stays the fused packed exchange
    assert CompressorConfig("mixed").resolved_wire().plane == "packed"


def test_solve_uplink_host_detailed_deprecated(problem):
    """The merged entrypoint returns the structured UplinkSolution
    (legacy 2-tuple unpack still works); _detailed is a warning shim
    delegating to it."""
    from repro.core.channel import CFmMIMOConfig, make_channel
    from repro.core.power import BisectionLPPowerControl
    train, test, cfg = problem
    shards = partition_iid(train, K)
    fl = FLConfig(L=1, T=1, batch_size=8, seed=0)
    eng = VectorizedFLEngine(
        train, test, shards, cfg, MixedResolutionQuantizer(0.2, 10),
        BisectionLPPowerControl(), make_channel(CFmMIMOConfig(K=K),
                                                seed=0), fl,
        engine=EngineConfig(wire=WirePath(plane="packed")))
    bits = np.full(K, 1000.0)
    active = np.ones(K)
    sol = eng.solve_uplink_host(eng.chan, bits, active)
    assert isinstance(sol, UplinkSolution)
    straggler, per_user = sol                   # legacy unpack
    assert per_user.shape == (K,)
    assert straggler == pytest.approx(float(np.max(per_user)))
    with pytest.warns(DeprecationWarning, match="detailed"):
        old = eng.solve_uplink_host_detailed(eng.chan, bits, active)
    np.testing.assert_array_equal(old.latencies, sol.latencies)


# ------------------------------------------------------- scale smoke
def _scale_problem(K_big):
    ds = make_image_classification(n_samples=K_big + 200, hw=8,
                                   n_classes=2, noise=0.3, seed=0)
    train = dataclasses.replace(ds, x=ds.x[:K_big], y=ds.y[:K_big])
    test = dataclasses.replace(ds, x=ds.x[K_big:], y=ds.y[K_big:])
    shards = [np.array([i]) for i in range(K_big)]
    cnn = PaperCNNConfig(input_hw=8, channels=3, conv_filters=4,
                         dense_units=8, n_classes=2)
    fl = FLConfig(T=1, L=1, batch_size=1, seed=0, eval_every=1)
    return VectorizedFLEngine(
        train, test, shards, cnn, MixedResolutionQuantizer(0.2, 10),
        None, None, fl,
        engine=EngineConfig(wire=WirePath(plane="packed",
                                          cohort_size=256)))


def test_k20000_trace_is_cohort_resident():
    """Tracing alone (no execution — cheap even at K=20 000): the
    full-scale step's jaxpr carries no [K, d] buffer, and the largest
    d-carrying intermediate is the cohort stack [C, d], so device
    residency scales with C, not K."""
    eng = _scale_problem(20_000)
    avals = _trace_step_avals(eng)
    d, C = eng.d, 256
    assert not _dense_user_buffers(avals, 20_000, d)
    biggest = max((a for a in avals if d in a.shape),
                  key=lambda a: int(np.prod(a.shape)))
    assert int(np.prod(biggest.shape)) <= C * d


scale_gate = pytest.mark.skipif(
    not os.environ.get("RUN_SCALE_TESTS"),
    reason="~1 min CPU smoke; set RUN_SCALE_TESTS=1 (the ci.yml "
           "'scale' suite does)")


@scale_gate
def test_k20000_cohort_round_completes():
    """Acceptance: one K=20 000, cohort_size=256 round end-to-end on
    the CPU runner — finite payload bits for every user, finite
    updated parameters."""
    eng = _scale_problem(20_000)
    state = eng.start_run()
    t0 = time.time()
    work = eng.train_round(state, 1)
    jax.block_until_ready(state.params)
    assert work.bits_np.shape == (20_000,)
    assert np.all(np.isfinite(work.bits_np)) and work.bits_np.min() > 0
    assert all(np.all(np.isfinite(l)) for l in _leaves(state.params))
    # generous ceiling so a CI runner regression still surfaces
    assert time.time() - t0 < 600
