"""Parity battery for the fused mixed-resolution wire kernels
(kernels/mixed_res.py + the ops.py wrappers) against the pure-jnp
reference ``mixed_resolution_quantize`` / ``mixed_recon`` paths.

Numerics contract (DESIGN.md section 9):

* the packed wire planes (signs, hi mask, codes) and the scalar header
  (inf, dw_q, step, dbar) are BIT-EXACT across the Pallas interpret
  lowering, the jnp lowering, and the eager reference's reductions;
* ``bits`` accounting is exact (dbar is an exact integer count);
* the decoded reconstruction is bit-exact on the jnp lowering and
  within 2 ulp of ``||x||_inf`` on the Pallas lowering (FMA
  contraction of ``dw_q + code * step`` inside the kernel).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.quantize import pack_signs, unpack_codes, unpack_signs
from repro.core.quantize.mixed_resolution import mixed_resolution_quantize
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.mixed_res import (H_DBAR, H_DWQ, H_INF, code_width,
                                     code_words_per_row,
                                     mixed_res_reduce)

ULP_BOUND = 2  # Pallas-lowering recon bound, in ulps of ||x||_inf


def heavy_tail(seed, U, d):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((U, d)).astype(np.float32)
    spikes = rng.choice(d, size=max(1, d // 64), replace=False)
    x[:, spikes] *= 50.0
    return jnp.asarray(x)


def reference(x, lam, b):
    """Per-user eager reference results for stacked [U, d] deltas."""
    return [mixed_resolution_quantize(x[u], lam, b)
            for u in range(x.shape[0])]


# --------------------------------------------------------------- pass A
@pytest.mark.parametrize("d,lam", [(4096, 0.2), (1000, 0.05),
                                   (257, 0.0), (8192, 0.9)])
def test_reduce_matches_reference_exactly(d, lam):
    """inf / dw_q / dbar from the streaming reduction == the jnp
    reference's reductions, bit for bit (max/min are associative; the
    count is an exact integer), including padded (d % tile != 0)."""
    x = heavy_tail(0, 3, d)
    x3 = ops.wire_view(x)
    stats = mixed_res_reduce(x3, lam, d, interpret=True)
    stats_ref = kref.mixed_res_reduce_ref(x3, lam, d)
    np.testing.assert_array_equal(np.asarray(stats),
                                  np.asarray(stats_ref))
    refs = reference(x, lam, 8)
    for u, r in enumerate(refs):
        assert float(stats[u, H_INF]) == float(r.aux["inf"])
        dwq_raw = float(stats[u, H_DWQ])
        dwq = dwq_raw if np.isfinite(dwq_raw) else 0.0
        assert dwq == float(r.aux["dw_q"])
        assert int(stats[u, H_DBAR]) == int(r.aux["dbar"])


# ------------------------------------------------- wire-format layout
def test_wire_planes_match_core_packing():
    """The emitted planes ARE the core/quantize/packing.py layouts:
    signs unpack with unpack_signs, codes with unpack_codes."""
    d, lam, b = 1000, 0.2, 8
    x = heavy_tail(1, 2, d)
    wire = ops.mixed_res_encode(x, lam, b, interpret=True,
                                use_kernel=True)
    assert wire.codes.shape[-1] == code_words_per_row(b)
    bw = code_width(b)
    for u in range(2):
        np.testing.assert_array_equal(
            np.asarray(wire.signs[u]).reshape(-1)[: -(-d // 32)],
            np.asarray(pack_signs(x[u])))
        signs = unpack_signs(wire.signs[u].reshape(-1), d)
        np.testing.assert_array_equal(np.asarray(signs),
                                      np.where(np.asarray(x[u]) > 0,
                                               1.0, -1.0))
        codes = unpack_codes(wire.codes[u].reshape(-1), bw,
                             x.shape[1])
        him = unpack_codes(wire.hi[u].reshape(-1), 1, d) > 0
        r = mixed_resolution_quantize(x[u], lam, b)
        absx = np.abs(np.asarray(x[u]))
        inf = float(r.aux["inf"])
        hi_ref = absx / inf >= lam
        np.testing.assert_array_equal(np.asarray(him), hi_ref)
        # hi codes reproduce the reference's rounded grid codes
        step = float(r.aux["r"]) / (2 ** b - 1)
        want = np.round((absx - float(r.aux["dw_q"]))
                        / (step if step > 0 else 1.0))
        np.testing.assert_array_equal(
            np.asarray(codes[:d])[hi_ref], want[hi_ref].astype(np.uint32))


def test_code_width_selection():
    assert [code_width(b) for b in (2, 3, 4, 8, 10, 16)] == \
        [2, 4, 4, 8, 16, 16]
    with pytest.raises(ValueError):
        code_width(17)


# ----------------------------------------------------------- roundtrip
@pytest.mark.parametrize("d,lam,b", [(4096, 0.2, 10), (1000, 0.05, 8),
                                     (256, 0.0, 4), (513, 0.9, 2),
                                     (2048, 0.3, 16)])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_roundtrip_matches_reference(d, lam, b, use_kernel):
    """encode -> dequant(weight 1) == mixed_resolution_quantize.recon:
    bit-exact on the jnp lowering, <= ULP_BOUND ulp on Pallas."""
    x = heavy_tail(2, 1, d)
    wire = ops.mixed_res_encode(x, lam, b, interpret=True,
                                use_kernel=use_kernel)
    out = ops.mixed_res_wire_reduce(wire, jnp.ones(1), b, d,
                                    interpret=True,
                                    use_kernel=use_kernel)
    r = mixed_resolution_quantize(x[0], lam, b)
    got, want = np.asarray(out), np.asarray(r.recon)
    if use_kernel:
        tol = ULP_BOUND * np.spacing(np.float32(r.aux["inf"]))
        np.testing.assert_allclose(got, want, rtol=0, atol=tol)
    else:
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("use_kernel", [True, False])
def test_bits_accounting_exact(use_kernel):
    """Payload bits replay the paper formula d(bs + 1 - s) + 32
    bit-for-bit against the reference (incl. the all-zero branch)."""
    d, lam, b = 3000, 0.2, 10
    x = np.array(heavy_tail(3, 4, d))
    x[2] = 0.0                                    # all-sign fallback
    x[3, :] = -0.75                               # step == 0 grid
    fx = jnp.asarray(x)
    _, bits, aux = ops.mixed_res_wire_aggregate(
        fx, jnp.full(4, 0.25), lam, b, interpret=True,
        use_kernel=use_kernel)
    refs = reference(fx, lam, b)
    np.testing.assert_array_equal(
        np.asarray(bits), np.asarray([float(r.bits) for r in refs]))
    np.testing.assert_array_equal(
        np.asarray(aux["s"]),
        np.asarray([float(r.aux["s"]) for r in refs]))
    np.testing.assert_array_equal(
        np.asarray(aux["dw_q"]),
        np.asarray([float(r.aux["dw_q"]) for r in refs]))


@pytest.mark.parametrize("use_kernel", [True, False])
def test_weighted_aggregate_matches_dense_einsum(use_kernel):
    """sum_k w_k * deq(wire_k) from packed buffers == the dense
    einsum over reference reconstructions (to the documented bound)."""
    d, lam, b, U = 2048, 0.15, 8, 5
    x = heavy_tail(4, U, d)
    w = jnp.asarray(np.random.default_rng(4).uniform(0.05, 0.4, U),
                    jnp.float32)
    agg, _, _ = ops.mixed_res_wire_aggregate(x, w, lam, b,
                                             interpret=True,
                                             use_kernel=use_kernel)
    refs = reference(x, lam, b)
    want = jnp.einsum("k,kd->d", w, jnp.stack([r.recon for r in refs]))
    np.testing.assert_allclose(np.asarray(agg), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


# -------------------------------------------------- hypothesis battery
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6), st.floats(0.0, 0.99),
       st.sampled_from([2, 4, 8, 10, 16]),
       st.sampled_from([96, 257, 512, 1000, 1300]),
       st.sampled_from(["normal", "zero", "constant", "one-spike"]))
def test_roundtrip_property(seed, lam, b, d, shape):
    """Edge-case sweep: all-zero deltas, step == 0 grids (constant
    magnitudes), single-spike spectra, d not a multiple of the tile."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(d).astype(np.float32)
    if shape == "zero":
        x[:] = 0.0
    elif shape == "constant":
        x = np.sign(x) * 2.5
        x[x == 0] = 2.5
    elif shape == "one-spike":
        x[:] = 0.0
        x[int(rng.integers(d))] = 7.0
    fx = jnp.asarray(x)[None]
    wire = ops.mixed_res_encode(fx, lam, b, interpret=True,
                                use_kernel=True)
    wire_ref = ops.mixed_res_encode(fx, lam, b, use_kernel=False)
    for a, bb in zip(wire, wire_ref):             # planes bit-exact
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))
    out = ops.mixed_res_wire_reduce(wire, jnp.ones(1), b, d,
                                    interpret=True, use_kernel=True)
    r = mixed_resolution_quantize(fx[0], lam, b)
    tol = ULP_BOUND * np.spacing(np.float32(max(float(r.aux["inf"]),
                                                1e-30)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(r.recon),
                               rtol=0, atol=tol)


# -------------------------------------------- anchored (repro.dist) path
@pytest.mark.parametrize("use_kernel", [True, False])
def test_anchored_matches_mixed_recon(use_kernel):
    """The static-budget (top-k anchored) emit + fused dequant-mean
    equals the dist reference mixed_recon roundtrip mean."""
    from repro.dist.compressor import (CompressorConfig, budget_k,
                                       mixed_recon, _rank_k_values)
    G, d = 4, 2048
    comp = CompressorConfig("mixed", s_budget=0.03, bits=8,
                            exact_topk=True)
    x = heavy_tail(5, G, d)
    k = budget_k(d, comp.s_budget)
    inf, dw_q = _rank_k_values(jnp.abs(x), k, True)
    wire = ops.mixed_res_encode_anchored(x, inf, dw_q, comp.bits,
                                         interpret=True,
                                         use_kernel=use_kernel)
    got = ops.mixed_res_wire_reduce(wire, jnp.full(G, 1.0 / G),
                                    comp.bits, d, interpret=True,
                                    use_kernel=use_kernel)
    recon, _ = mixed_recon(x, comp)
    want = jnp.mean(recon, axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("use_kernel", [True, False])
def test_anchored_underestimated_inf_stays_element_local(use_kernel):
    """An approx-top-k anchor can underestimate inf; overflowing codes
    must clamp to the grid top instead of spilling shifted bits into
    NEIGHBORING packed code slots (regression: unclamped emit
    corrupted other elements' decoded values)."""
    d, b = 256, 8
    x = np.full(d, 0.5, np.float32)
    x[5] = 100.0                       # true max, missed by the anchor
    x[6] = 9.0                         # neighbor in the same code word
    fx = jnp.asarray(x)[None]
    inf, dw_q = jnp.asarray([10.0]), jnp.asarray([1.0])
    wire = ops.mixed_res_encode_anchored(fx, inf, dw_q, b,
                                         interpret=True,
                                         use_kernel=use_kernel)
    out = np.asarray(ops.mixed_res_wire_reduce(
        wire, jnp.ones(1), b, d, interpret=True,
        use_kernel=use_kernel))
    step = (10.0 - 1.0) / (2 ** b - 1)
    # neighbor decodes from ITS OWN code, unaffected by the overflow
    np.testing.assert_allclose(
        out[6], 1.0 + np.round((9.0 - 1.0) / step) * step, rtol=1e-6)
    # the overflowing element caps at the grid top (element-local)
    np.testing.assert_allclose(out[5], 1.0 + (2 ** b - 1) * step,
                               rtol=1e-6)


def test_threshold_encode_rejects_d_past_exact_count():
    """The f32 dbar count is exact only to 2**24 — the threshold
    encode must refuse identically on every backend/lowering."""
    big = jnp.zeros((1, 2 ** 24), jnp.float32)
    with pytest.raises(ValueError, match="2\\*\\*24"):
        ops.mixed_res_encode(big, 0.2, 8, use_kernel=False)


def test_dist_fused_wire_matches_reference_path():
    """aggregate_flat_stacked: wire_path='fused' == 'reference' to
    float32 roundoff (different reduce fusion, same arithmetic)."""
    import dataclasses

    from repro.dist.compressor import (CompressorConfig,
                                       aggregate_flat_stacked)
    x = heavy_tail(6, 6, 1500)
    comp = CompressorConfig("mixed", s_budget=0.02, bits=4,
                            exact_topk=True)
    fused = aggregate_flat_stacked(x, comp)
    refp = aggregate_flat_stacked(
        x, dataclasses.replace(comp, wire_path="reference"))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(refp),
                               rtol=2e-6, atol=2e-6)


# ------------------------------------------------------- TPU tiling
def test_kernel_tiling_is_tpu_shaped():
    """The Pallas launches keep the quant_pack.py conventions: 128-lane
    last dims, uint32 word planes, and VMEM-bounded tiles."""
    from repro.kernels.mixed_res import BLOCK_ROWS, HEADER_LANES
    d, b = 128 * 1024, 10
    x = ops.wire_view(jnp.zeros((1, d), jnp.float32))
    U, W, lanes = x.shape
    assert lanes == 128 and W % BLOCK_ROWS == 0
    bm = min(BLOCK_ROWS, W)
    bw = code_width(b)
    # per-tile VMEM residency: x tile + sign/hi/code tiles + header
    tile_bytes = (bm * 128 * 4 + 2 * bm * 4 * 4
                  + bm * code_words_per_row(b) * 4 + HEADER_LANES * 4)
    assert tile_bytes < 2 ** 20            # well under ~16 MB VMEM
    assert 128 * bw % 32 == 0              # code words tile the row
    wire = ops.mixed_res_encode(jnp.ones((1, d)), 0.2, b,
                                interpret=True, use_kernel=True)
    assert wire.signs.dtype == jnp.uint32
    assert wire.codes.shape == (1, W, code_words_per_row(b))
