"""Dedicated checkpoint/io coverage: nested-pytree roundtrips,
retention pruning, latest_step discovery, metadata fidelity, and the
mismatched-template error path (previously only incidentally touched
by test_substrate.py)."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import (latest_step, restore_checkpoint,
                                 save_checkpoint)


def _nested_tree():
    return {
        "params": {
            "conv": {"w": jnp.arange(24, dtype=jnp.float32).reshape(2, 3, 4),
                     "b": jnp.ones(4)},
            "head": [jnp.zeros((3, 2)), jnp.full((2,), -1.5)],
        },
        "opt_state": {"accum": jnp.linspace(0.0, 1.0, 7)},
        "step_count": jnp.asarray(17, dtype=jnp.int32),
    }


def _leaves(tree):
    import jax
    return jax.tree_util.tree_leaves(tree)


def test_save_restore_roundtrip_nested(tmp_path):
    d = str(tmp_path)
    tree = _nested_tree()
    path = save_checkpoint(d, 3, tree)
    assert os.path.exists(path) and path.endswith("ckpt_00000003.npz")
    out, step, meta = restore_checkpoint(d, tree)
    assert step == 3 and meta == {}
    for a, b in zip(_leaves(tree), _leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_restore_specific_step_among_many(tmp_path):
    d = str(tmp_path)
    for s in (1, 5, 9):
        save_checkpoint(d, s, {"a": jnp.full(3, float(s))})
    out, step, _ = restore_checkpoint(d, {"a": jnp.zeros(3)}, step=5)
    assert step == 5
    np.testing.assert_array_equal(out["a"], np.full(3, 5.0))


def test_keep_retention_prunes_oldest(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.zeros(2)}
    for s in range(7):
        save_checkpoint(d, s, tree, keep=2)
    files = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert files == ["ckpt_00000005.npz", "ckpt_00000006.npz"]
    # pruned steps are gone; restoring one must fail at file level
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(d, tree, step=0)


def test_keep_larger_than_count_keeps_all(tmp_path):
    d = str(tmp_path)
    for s in range(3):
        save_checkpoint(d, s, {"a": jnp.zeros(1)}, keep=10)
    assert len([f for f in os.listdir(d) if f.endswith(".npz")]) == 3


def test_latest_step_empty_and_populated(tmp_path):
    d = str(tmp_path / "ckpts")
    assert latest_step(d) is None          # directory does not exist
    os.makedirs(d)
    assert latest_step(d) is None          # exists but empty
    save_checkpoint(d, 2, {"a": jnp.zeros(1)})
    assert latest_step(d) == 2
    save_checkpoint(d, 10, {"a": jnp.zeros(1)})
    assert latest_step(d) == 10            # numeric, not lexicographic


def test_restore_from_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="no checkpoints"):
        restore_checkpoint(str(tmp_path), {"a": jnp.zeros(1)})


def test_metadata_fidelity(tmp_path):
    d = str(tmp_path)
    meta_in = {"lr": 0.01, "note": "mid-run", "shards": [3, 5],
               "nested": {"tag": "x"}}
    save_checkpoint(d, 4, {"a": jnp.zeros(1)}, metadata=meta_in)
    _, step, meta = restore_checkpoint(d, {"a": jnp.zeros(1)})
    assert step == 4
    assert meta == meta_in                 # JSON roundtrip, exact


def test_restore_against_mismatched_template(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": jnp.zeros((2, 3)), "b": jnp.ones(4)})
    # wrong leaf shape -> explicit shape error naming the leaf
    with pytest.raises(ValueError, match="shape mismatch for a"):
        restore_checkpoint(d, {"a": jnp.zeros((3, 2)), "b": jnp.ones(4)})
    # template with a key the checkpoint never saved -> KeyError from
    # the archive lookup
    with pytest.raises(KeyError):
        restore_checkpoint(d, {"a": jnp.zeros((2, 3)),
                               "c": jnp.ones(4)})
