"""CFmMIMO channel + power-control tests."""
import numpy as np
import pytest

from repro.core.channel import (CFmMIMOConfig, computation_latency,
                                make_channel, uplink_latency)
from repro.core.power import (BisectionLPPowerControl,
                              DinkelbachPowerControl,
                              MaxSumRatePowerControl, eta_upper_bound,
                              make_power_controller,
                              rate_aware_fractions,
                              equalizing_target_latency)


@pytest.fixture(scope="module")
def chan():
    return make_channel(CFmMIMOConfig(K=20), seed=0)


@pytest.fixture(scope="module")
def chan40():
    return make_channel(CFmMIMOConfig(K=40), seed=1)


def test_channel_shapes_and_positivity(chan):
    cfg = chan.cfg
    assert chan.beta.shape == (cfg.M, cfg.K)
    assert chan.gamma.shape == (cfg.M, cfg.K)
    assert np.all(chan.beta > 0) and np.all(chan.gamma > 0)
    assert np.all(chan.gamma <= chan.beta + 1e-18)  # estimation quality <= beta
    assert np.all(chan.A_bar > 0) and np.all(chan.I_M > 0)
    assert np.all(np.diag(chan.B_tilde) == 0.0)


def test_pilot_assignment(chan40):
    cfg = chan40.cfg
    assert chan40.pilot.shape == (cfg.K,)
    assert np.all(chan40.pilot < cfg.tau_p)
    # first tau_p users orthogonal
    assert len(set(chan40.pilot[: cfg.tau_p].tolist())) == cfg.tau_p


def test_sinr_monotone_in_own_power(chan):
    p = np.full(chan.cfg.K, 0.5)
    s0 = chan.sinr(p)
    p2 = p.copy()
    p2[3] = 1.0
    s1 = chan.sinr(p2)
    assert s1[3] > s0[3]          # own SINR increases
    assert np.all(np.delete(s1, 3) <= np.delete(s0, 3) + 1e-12)  # others hurt


def test_rates_reasonable_spectral_efficiency(chan):
    """Full power: per-user SE should be in a physically sane range."""
    rates = chan.rates(np.ones(chan.cfg.K))
    se = rates / chan.cfg.bandwidth_hz
    assert np.all(rates > 0)
    assert np.all(se < 25.0), se.max()   # not absurd
    assert np.median(se) > 0.05, se      # not dead either


def test_uplink_latency_eq12(chan):
    rates = chan.rates(np.ones(chan.cfg.K))
    bits = np.full(chan.cfg.K, 1e6)
    lat = uplink_latency(bits, rates)
    np.testing.assert_allclose(lat, 1e6 / rates)


def test_computation_latency_table3():
    # L=5, |D|=5e4, K=40, a=1e6 cycles/sample, nu=20 cycles/s scaled
    ell = computation_latency(5, 50_000, 40)
    assert ell > 0


# ------------------------------------------------------------ power control
def test_bisection_lp_reduces_straggler(chan):
    rng = np.random.default_rng(0)
    bits = rng.uniform(1e5, 2e6, chan.cfg.K)  # heterogeneous payloads
    ours = BisectionLPPowerControl().solve(chan, bits)
    full = MaxSumRatePowerControl(iters=0).solve(chan, bits)  # p = 1
    assert ours.straggler_latency <= full.straggler_latency * (1 + 1e-6)
    assert np.all(ours.p >= 0) and np.all(ours.p <= 1)
    assert ours.info["eta"] > 0


def test_bisection_eta_is_min_rate_per_bit(chan):
    bits = np.full(chan.cfg.K, 1e6)
    sol = BisectionLPPowerControl().solve(chan, bits)
    eta_real = np.min(sol.rates / bits)
    # achieved min rate-per-bit >= certified eta (bisection lower bound)
    assert eta_real >= sol.info["eta"] * (1 - 1e-3)
    assert sol.info["eta"] <= eta_upper_bound(chan, bits)


def test_bisection_latency_equalization(chan):
    """With equal bits, optimal min-max powers should roughly equalize
    latencies (the straggler gap shrinks vs full power)."""
    bits = np.full(chan.cfg.K, 1e6)
    ours = BisectionLPPowerControl().solve(chan, bits)
    full = MaxSumRatePowerControl(iters=0).solve(chan, bits)
    spread_ours = ours.straggler_latency / np.min(ours.latencies)
    spread_full = full.straggler_latency / np.min(full.latencies)
    assert spread_ours < spread_full


def test_dinkelbach_converges(chan):
    bits = np.full(chan.cfg.K, 1e6)
    sol = DinkelbachPowerControl(outer=6, inner=20).solve(chan, bits)
    assert sol.info["energy_efficiency"] > 0
    assert np.all((0 <= sol.p) & (sol.p <= 1))


def test_maxsum_improves_sum_rate(chan):
    bits = np.full(chan.cfg.K, 1e6)
    opt = MaxSumRatePowerControl(iters=40, restarts=1).solve(chan, bits)
    base = MaxSumRatePowerControl(iters=0).solve(chan, bits)
    assert opt.info["sum_rate"] >= np.sum(np.log2(1 + chan.sinr(base.p))) - 1e-9


def test_registry_power():
    for name in ["bisection-lp", "dinkelbach", "max-sum-rate"]:
        assert make_power_controller(name).name == name
    with pytest.raises(KeyError):
        make_power_controller("nope")


def test_rate_aware_bitalloc():
    rates = np.array([1e6, 2e6, 4e6])
    d, b = 100_000, 10
    ell = equalizing_target_latency(rates, d, b, s_floor=0.01)
    s = rate_aware_fractions(rates, d, b, ell, s_min=0.01, s_max=1.0)
    bits = d * (b * s + 1 - s) + 32
    lat = bits / rates
    assert np.all(s >= 0.01 - 1e-12)
    # faster links get bigger budgets; latencies equalized at the target
    assert s[2] >= s[1] >= s[0]
    np.testing.assert_allclose(lat.max(), ell, rtol=1e-6)
