"""Property tests for the batched power solvers (PowerSolution
invariants from the ISSUE checklist):

* allocated power lies in [0, p_max] — coefficients in [0, 1], zero
  for masked users;
* the Dinkelbach energy-efficiency objective is non-decreasing across
  outer iterations (the solver reports the running-best iterate, so
  the trace is monotone by contract — asserted against the actual
  trace);
* the bisection-LP scheme's straggler latency is no worse than
  max-sum-rate's on the same realization and payloads (max-sum
  ignores payloads; minimizing the max latency is bisection's
  objective).

Deterministic versions run everywhere; hypothesis widens the sampled
(geometry seed, payload spread, churn) space when installed.
"""
import jax
import numpy as np

from repro.core.channel import CFmMIMOConfig, make_channel
from repro.phy import (bisection_solve, bundle_from_realizations,
                       dinkelbach_solve, maxsum_solve)

from _hypothesis_compat import given, settings, st

X64 = bool(jax.config.jax_enable_x64)
# bisection certifies eta within eps_rel of the optimum, so its
# straggler can exceed an accidentally-optimal competitor's by the
# same relative margin
BISECTION_SLACK = 1e-3


def _problem(seed: int, k: int = 8, m: int = 4, spread: float = 10.0,
             participation: float = 1.0):
    cfg = CFmMIMOConfig(K=k, M=m)
    chans = [make_channel(cfg, seed=seed + i) for i in range(4)]
    rng = np.random.default_rng(seed)
    bits = rng.uniform(1e5, 1e5 * spread, (4, k))
    mask = (rng.random((4, k)) < participation).astype(np.float64)
    mask[mask.sum(axis=1) == 0, 0] = 1.0
    bits = np.where(mask > 0, np.maximum(bits, 1.0), 1.0)
    return bundle_from_realizations(chans), bits, mask


def _check_power_box(sol, mask):
    p = np.asarray(sol.p, np.float64)
    assert np.all(p >= 0.0) and np.all(p <= 1.0)       # power <= p_max
    assert np.all(p[mask == 0] == 0.0)                 # absent: no power
    assert np.all(np.isfinite(np.asarray(sol.latencies)))


def _run_all(seed, spread, participation):
    cb, bits, mask = _problem(seed, spread=spread,
                              participation=participation)
    ours = bisection_solve(cb, bits, mask=mask)
    dink = dinkelbach_solve(cb, bits, mask=mask)
    msum = maxsum_solve(cb, bits, mask=mask)
    for sol in (ours, dink, msum):
        _check_power_box(sol, mask)
    # Dinkelbach EE trace monotone (running-best contract)
    trace = np.asarray(dink.info["ee_trace"], np.float64)
    assert np.all(np.diff(trace, axis=-1) >= 0.0)
    assert np.all(trace > 0.0)
    # straggler: ours <= max-sum on identical realization + payloads
    ours_lat = np.asarray(ours.straggler_latency, np.float64)
    msum_lat = np.asarray(msum.straggler_latency, np.float64)
    assert np.all(ours_lat <= msum_lat * (1.0 + BISECTION_SLACK)), \
        (ours_lat, msum_lat)


def test_invariants_full_participation():
    _run_all(seed=0, spread=20.0, participation=1.0)


def test_invariants_under_churn():
    _run_all(seed=7, spread=10.0, participation=0.6)


def test_invariants_equal_payloads():
    _run_all(seed=3, spread=1.0, participation=1.0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**20),
       spread=st.floats(min_value=1.0, max_value=50.0),
       participation=st.floats(min_value=0.3, max_value=1.0))
def test_invariants_hypothesis(seed, spread, participation):
    _run_all(seed, spread, participation)
