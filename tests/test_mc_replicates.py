"""Monte-Carlo replicate axis (repro.sim.phy_driver, DESIGN.md §8).

The contract the ISSUE pins:

* R=1 replicated driver == unreplicated batched driver bit-for-bit on
  training metrics (bits, accuracy, mean_s) — the R=1 path routes
  through the IDENTICAL compiled step, no vmap — with latency compared
  under the DESIGN.md §7 tolerances (here it is the same jitted solve
  on the same bundle, so the tolerance is tight);
* R=4 trajectories are pairwise distinct (RNG-stream and channel-draw
  independence) and the reported ``mean``/``ci95`` columns equal the
  host-computed statistics of the per-replicate summaries exactly;
* one jitted train call per quantizer per round and one power solve
  per power spec per round REGARDLESS of R (dispatch-count test).
"""
import dataclasses

import jax
import numpy as np
import pytest

import repro.sim.phy_driver as phy_driver
from repro.sim import (VectorizedFLEngine, get_scenario, run_grid,
                       run_grid_batched, summarize_logs)

# engine trains in float32 (see tests/test_phy_driver.py); the x64 CI
# leg covers solver parity separately
pytestmark = pytest.mark.skipif(
    bool(jax.config.jax_enable_x64),
    reason="engine trains in float32; x64 leg covers solver parity")

QUANTIZERS = {"mixed": ("mixed-resolution", {"lambda_": 0.2, "b": 4}),
              "classic": ("classic", {})}
POWERS = {"ours": "bisection-lp", "maxsum": "max-sum-rate"}


def _tiny(name, **overrides):
    fields = dict(K=4, T=4, n_train=240, n_test=60, batch_size=8, L=1,
                  name=f"{name}-tiny")
    fields.update(overrides)
    return dataclasses.replace(get_scenario(name), **fields)


# ------------------------------------------------------- R=1 parity
@pytest.fixture(scope="module")
def parity_runs():
    scn = _tiny("churn-0.7", participation=0.5)
    legacy = run_grid_batched([scn], QUANTIZERS, POWERS, quick=False)
    rep1 = run_grid_batched([scn], QUANTIZERS, POWERS, quick=False,
                            replicates=1)
    return legacy, rep1


def test_r1_training_metrics_bit_for_bit(parity_runs):
    legacy, rep1 = parity_runs
    assert len(legacy) == len(rep1) == 4
    for rl, rr in zip(legacy, rep1):
        assert (rl.cell.quantizer_label, rl.cell.power_label) \
            == (rr.cell.quantizer_label, rr.cell.power_label)
        assert len(rr.result) == 1          # per-replicate FLResult list
        ll, lr = rl.result.logs, rr.result[0].logs
        assert len(ll) == len(lr)
        for a, b in zip(ll, lr):
            np.testing.assert_array_equal(a.bits_per_user,
                                          b.bits_per_user)
            assert a.test_acc == b.test_acc
            assert a.mean_s == b.mean_s


def test_r1_latency_parity(parity_runs):
    """R=1 stacks the same bundle and runs the same jitted solve as
    the unreplicated driver, so latency parity is tight — far inside
    the DESIGN.md §7 f32 driver tolerance (2e-2)."""
    legacy, rep1 = parity_runs
    for rl, rr in zip(legacy, rep1):
        for a, b in zip(rl.result.logs, rr.result[0].logs):
            np.testing.assert_allclose(a.uplink_latency_s,
                                       b.uplink_latency_s, rtol=1e-9)
        np.testing.assert_allclose(rl.summary["total_latency_s"],
                                   rr.summary["total_latency_s"],
                                   rtol=1e-9)
        np.testing.assert_allclose(rl.summary["max_p"],
                                   rr.summary["max_p"], rtol=1e-9)


def test_r1_summary_is_degenerate_point_estimate(parity_runs):
    """At R=1 every mean column equals the single replicate's summary
    and every ci95 column is exactly 0 (a point estimate has no
    width)."""
    _, rep1 = parity_runs
    for r in rep1:
        assert r.summary["replicates"] == 1.0
        single = summarize_logs(r.result[0].logs)
        for key, val in single.items():
            np.testing.assert_array_equal(r.summary[key], val)
            assert r.summary[key + "_ci95"] == 0.0


# --------------------------------------------- R=4 replicate statistics
@pytest.fixture(scope="module")
def r4_run():
    scn = _tiny("monte-carlo-channel")
    return run_grid_batched(
        [scn], {"mixed": QUANTIZERS["mixed"]}, {"ours": "bisection-lp"},
        quick=False, replicates=4)[0]


def test_r4_trajectories_pairwise_distinct(r4_run):
    """RNG-stream independence: no two replicates draw the same
    round-1 minibatches (payload bits differ) or the same channel
    (uplink latencies differ)."""
    bits = [tuple(np.asarray(res.logs[0].bits_per_user))
            for res in r4_run.result]
    assert len(set(bits)) == 4
    uplinks = [tuple(log.uplink_latency_s for log in res.logs)
               for res in r4_run.result]
    assert len(set(uplinks)) == 4
    # and the final models differ too
    finals = [np.concatenate([np.ravel(np.asarray(leaf)) for leaf in
                              jax.tree_util.tree_leaves(res.params)])
              for res in r4_run.result]
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(finals[i], finals[j])


def test_r4_mean_and_ci95_match_host_computation(r4_run):
    """The reported mean column IS np.mean of the per-replicate
    summaries (exactly — same arithmetic), and ci95 is the normal 95%
    half-width 1.96 * std(ddof=1) / sqrt(R)."""
    rows = [summarize_logs(res.logs) for res in r4_run.result]
    assert r4_run.summary["replicates"] == 4.0
    for key in rows[0]:
        vals = np.array([row[key] for row in rows])
        np.testing.assert_array_equal(r4_run.summary[key],
                                      float(np.mean(vals)))
        np.testing.assert_array_equal(
            r4_run.summary[key + "_ci95"],
            float(1.96 * np.std(vals, ddof=1) / np.sqrt(4)))


def test_r4_ci_widths_finite_and_informative(r4_run):
    """Monte-Carlo channel redraws make latency genuinely random, so
    the latency CI is finite and strictly positive; power stays
    physical across all replicates."""
    s = r4_run.summary
    for f in ("total_latency_s", "mean_uplink_s", "p95_uplink_s"):
        assert np.isfinite(s[f]) and s[f] > 0
        assert np.isfinite(s[f + "_ci95"]) and s[f + "_ci95"] > 0
    assert 0.0 < s["max_p"] <= 1.0


# -------------------------------------------------- dispatch counting
def _counting_run(monkeypatch, R):
    calls = {"train": 0, "solve": 0}
    orig_step = VectorizedFLEngine._replicated_step
    orig_solver = phy_driver.batched_solver

    def counting_step(self, n):
        fn = orig_step(self, n)

        def wrapper(*args, **kwargs):
            calls["train"] += 1
            return fn(*args, **kwargs)
        return wrapper

    def counting_solver(ctrl):
        fn = orig_solver(ctrl)

        def wrapper(*args, **kwargs):
            calls["solve"] += 1
            return fn(*args, **kwargs)
        return wrapper

    monkeypatch.setattr(VectorizedFLEngine, "_replicated_step",
                        counting_step)
    monkeypatch.setattr(phy_driver, "batched_solver", counting_solver)
    scn = _tiny("churn-0.7", T=3)
    run_grid_batched([scn], QUANTIZERS, POWERS, quick=False,
                     replicates=R)
    return calls


@pytest.mark.parametrize("R", [1, 4])
def test_one_dispatch_per_quantizer_and_power_spec_per_round(
        monkeypatch, R):
    """The acceptance criterion: O(quantizers + power specs) device
    dispatches per round REGARDLESS of the replicate count."""
    calls = _counting_run(monkeypatch, R)
    T = 3
    assert calls["train"] == len(QUANTIZERS) * T
    assert calls["solve"] == len(POWERS) * T


# ----------------------------------------------------- plumbing & API
def test_scenario_replicates_field_routes_to_replicated_driver():
    """A Scenario declaring replicates > 1 gets the replicate axis
    without the caller passing replicates=."""
    scn = dataclasses.replace(_tiny("monte-carlo-replicated", T=2),
                              replicates=2)
    res = run_grid_batched([scn], {"classic": ("classic", {})},
                           {"ours": "bisection-lp"}, quick=False)
    assert res[0].summary["replicates"] == 2.0
    assert len(res[0].result) == 2


def test_run_grid_passes_replicates_through():
    scn = _tiny("paper-table3", T=2)
    res = run_grid([scn], {"classic": ("classic", {})},
                   {"ours": "bisection-lp"}, quick=False,
                   phy_batched=True, replicates=2)
    assert res[0].summary["replicates"] == 2.0
    assert np.isfinite(res[0].summary["total_latency_s_ci95"])


def test_run_grid_rejects_replicates_without_phy_batched():
    with pytest.raises(ValueError, match="phy_batched"):
        run_grid([_tiny("paper-table3")], {"classic": ("classic", {})},
                 replicates=2)


def test_replicated_mode_requires_fused_engine():
    from repro.sim import EngineConfig
    from repro.sim.scenarios import build_problem
    from repro.fl.loop import FLConfig
    from repro.core.quantize import ClassicQuantizer

    scn = _tiny("paper-table2", T=1)
    train, test, shards, cnn_cfg, chan = build_problem(scn)
    fl = FLConfig(L=1, T=1, batch_size=8, seed=0)
    eng = VectorizedFLEngine(train, test, shards, cnn_cfg,
                             ClassicQuantizer(), None, chan, fl,
                             engine=EngineConfig(fused=False))
    with pytest.raises(ValueError, match="fused"):
        eng.start_replicated_run(2)
    with pytest.raises(ValueError, match="replicate"):
        run_grid_batched([scn], {"classic": ("classic", {})},
                         quick=False, replicates=0)
