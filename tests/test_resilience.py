"""Resilience layer: inject / detect / recover (PR 10, DESIGN.md §14).

The contracts the ISSUE pins:

* no-fault parity battery: an armed engine with ``FaultPlan.none()``
  is BIT-FOR-BIT identical to ``resilience=None`` — payload bits,
  accuracy, every parameter — on the sync packed path, the
  cohort-streamed scan, the checksummed wire, the async event-clock
  engine and the replicated (R=2) driver;
* every fault axis is detected and survived: NaN/Inf deltas and
  mid-upload dropouts quarantine (weights renormalized, params stay
  finite), sign-plane bitflips are caught exactly when
  ``WirePath(checksum=True)``, forced solver non-convergence routes
  through the bounded fallback chain, channel-estimate corruption is
  rebuilt transparently;
* ``guards=False`` measures the blast radius: the same NaN injection
  poisons the dense aggregate (why detection ships on by default);
* the xor-fold checksum word and the head-based finite guards as
  units;
* checkpoint-restore hardening (corrupt newest -> fall back to the
  next retained step with a warning), the atomic metrics CSV, and
  cell-granular sweep checkpoint/resume — including the gated
  ``RUN_CHAOS_TESTS=1`` kill -9 subprocess test (``kill_after_rounds``
  preemption followed by a resume that completes the grid).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import (latest_step, restore_checkpoint,
                                 save_checkpoint)
from repro.configs.paper_cnn import PaperCNNConfig
from repro.core.quantize import MixedResolutionQuantizer
from repro.data import make_image_classification, partition_iid
from repro.fl import FLConfig
from repro.kernels import WirePath
from repro.kernels.ops import (mixed_res_encode, mixed_res_wire_reduce,
                               verify_wire)
from repro.kernels.ref import xor_fold_words_ref
from repro.resilience import FaultPlan, ResilienceConfig, guards
from repro.sim import (EngineConfig, StalenessConfig,
                       VectorizedFLEngine, get_scenario,
                       run_grid_batched, write_metrics_csv)

pytestmark = pytest.mark.skipif(
    bool(jax.config.jax_enable_x64),
    reason="engine trains in float32; x64 leg covers solver parity")

K = 7
LAM, B = 0.2, 10
QUANTIZERS = {"mixed": ("mixed-resolution", {"lambda_": 0.2, "b": 4})}
POWERS = {"ours": "bisection-lp"}


def _tiny(base, **overrides):
    fields = dict(K=4, T=4, n_train=240, n_test=60, batch_size=8, L=1,
                  name=f"{base}-res-tiny")
    fields.update(overrides)
    return dataclasses.replace(get_scenario(base), **fields)


# ------------------------------------------------------- guard units
def test_xor_fold_matches_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 7, 32, 1000):
        w = rng.integers(0, 2 ** 32, size=(5, n), dtype=np.uint64) \
               .astype(np.uint32)
        got = np.asarray(xor_fold_words_ref(jnp.asarray(w)))
        np.testing.assert_array_equal(
            got, np.bitwise_xor.reduce(w, axis=1))


def test_checksum_detects_single_bitflip():
    rng = np.random.default_rng(1)
    flat = jnp.asarray(rng.standard_normal((3, 512)), jnp.float32)
    wire = mixed_res_encode(flat, LAM, B,
                            path=WirePath(plane="packed", checksum=True))
    np.testing.assert_array_equal(np.asarray(verify_wire(wire)), True)
    signs = np.asarray(wire.signs).copy()
    signs[1].flat[3] ^= np.uint32(1 << 17)
    flipped = wire._replace(signs=jnp.asarray(signs))
    np.testing.assert_array_equal(np.asarray(verify_wire(flipped)),
                                  [True, False, True])


def test_head_finite_flags_nonfinite_rows():
    rng = np.random.default_rng(2)
    flat = rng.standard_normal((5, 256)).astype(np.float32)
    flat[1, 7] = np.nan
    flat[3] = np.inf
    wire = mixed_res_encode(jnp.asarray(flat), LAM, B,
                            path=WirePath(plane="packed"))
    np.testing.assert_array_equal(np.asarray(guards.head_finite(wire)),
                                  [True, False, True, False, True])


def test_sanitize_head_equals_renormalized_good_rows():
    """A quarantined wire contributes exactly 0 to the fold: the
    aggregate equals the good-row aggregate under renormalized rho."""
    wp = WirePath(plane="packed", checksum=True)
    d = 1024
    rng = np.random.default_rng(3)
    base = rng.standard_normal((K, d)).astype(np.float32)
    bad = base.copy()
    bad[2] = np.nan
    w = jnp.full((K,), 1.0 / K, jnp.float32)

    wire = mixed_res_encode(jnp.asarray(bad), LAM, B, path=wp)
    good = guards.head_finite(wire)
    wire = guards.sanitize_head(wire, good)
    ok = guards.payload_ok(good, wire, True)
    w_eff, _ = guards.quarantine_weights(w, ok)
    agg = mixed_res_wire_reduce(wire, w_eff, B, d, path=wp)

    keep = np.flatnonzero(np.asarray(ok))
    assert list(keep) == [i for i in range(K) if i != 2]
    w_ref = jnp.full((len(keep),), 1.0 / len(keep), jnp.float32)
    ref = mixed_res_wire_reduce(
        mixed_res_encode(jnp.asarray(base[keep]), LAM, B, path=wp),
        w_ref, B, d, path=wp)
    np.testing.assert_allclose(np.asarray(agg), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_no_fault_guard_pipeline_is_bitwise_identity():
    """Zero fault arrays + all-good masks: every inject/sanitize/
    quarantine primitive returns its input's exact bits."""
    rng = np.random.default_rng(4)
    flat = jnp.asarray(rng.standard_normal((K, 256)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, K), jnp.float32)
    faults = {k: jnp.asarray(v)
              for k, v in guards.zero_fault_arrays(K).items()}
    np.testing.assert_array_equal(
        np.asarray(guards.inject_delta_faults(flat, faults)),
        np.asarray(flat))
    wire = mixed_res_encode(flat, LAM, B,
                            path=WirePath(plane="packed", checksum=True))
    flipped = guards.inject_bitflips(wire, faults)
    for a, b in zip(wire, flipped):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    good = guards.head_finite(wire)
    sanitized = guards.sanitize_head(wire, good)
    np.testing.assert_array_equal(np.asarray(sanitized.head),
                                  np.asarray(wire.head))
    w_eff, ok = guards.quarantine_weights(
        w, guards.payload_ok(good, wire, True))
    np.testing.assert_array_equal(np.asarray(ok), True)
    np.testing.assert_array_equal(np.asarray(w_eff), np.asarray(w))


def test_quarantine_weights_renormalizes():
    w = jnp.asarray([0.2, 0.3, 0.5], jnp.float32)
    ok = jnp.asarray([True, False, True])
    w_eff, _ = guards.quarantine_weights(w, ok)
    w_eff = np.asarray(w_eff)
    assert w_eff[1] == 0.0
    np.testing.assert_allclose(w_eff.sum(), 1.0, rtol=1e-6)
    np.testing.assert_allclose(w_eff[0] / w_eff[2], 0.2 / 0.5,
                               rtol=1e-6)


def test_fault_plan_draws_are_seeded_and_typed():
    plan = FaultPlan(nan_delta_prob=0.5, bitflip_prob=0.5,
                     dropout_prob=0.5, seed=7)
    a, b = plan.draw(3, 16), plan.draw(3, 16)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert not all(np.array_equal(a[k], plan.draw(4, 16)[k])
                   for k in ("nan", "flip_mask", "drop"))
    assert a["flip_mask"].dtype == np.uint32
    assert FaultPlan.none().is_none
    assert not plan.is_none
    # armed flips are single-bit masks
    nz = a["flip_mask"][a["flip_mask"] > 0]
    assert all(m & (m - 1) == 0 for m in nz)


# ---------------------------------------------- engine parity battery
@pytest.fixture(scope="module")
def problem():
    full = make_image_classification(n_samples=360, hw=8, n_classes=3,
                                     noise=0.25, seed=0)
    train = dataclasses.replace(full, x=full.x[:280], y=full.y[:280])
    test = dataclasses.replace(full, x=full.x[280:], y=full.y[280:])
    cfg = PaperCNNConfig(input_hw=8, n_classes=3)
    return train, test, cfg


def _engine(problem, wire, resilience=None, T=3, fused=True,
            quantizer=None, **ecfg_kw):
    train, test, cfg = problem
    shards = partition_iid(train, K)
    fl = FLConfig(L=2, T=T, batch_size=8, alpha=0.02, eval_every=1,
                  seed=0)
    q = quantizer or MixedResolutionQuantizer(lambda_=0.2, b=10)
    return VectorizedFLEngine(
        train, test, shards, cfg, q, None, None, fl,
        engine=EngineConfig(wire=wire, fused=fused,
                            resilience=resilience, **ecfg_kw))


def _leaves(params):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(params)]


def _assert_runs_identical(a, b):
    assert len(a.logs) == len(b.logs)
    for la, lb in zip(a.logs, b.logs):
        np.testing.assert_array_equal(la.bits_per_user, lb.bits_per_user)
        assert la.test_acc == lb.test_acc
        assert la.mean_s == lb.mean_s
    for x, y in zip(_leaves(a.params), _leaves(b.params)):
        np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("wire", [
    WirePath(plane="packed"),
    WirePath(plane="packed", checksum=True),
    WirePath(plane="packed", cohort_size=3),
    WirePath(plane="packed", cohort_size=3, checksum=True),
], ids=["packed", "checksum", "cohort", "cohort-checksum"])
def test_no_fault_parity_engine_paths(problem, wire):
    """ResilienceConfig.none() is bit-for-bit with resilience=None on
    every packed engine path — the acceptance criterion."""
    base = _engine(problem, wire).run()
    armed = _engine(problem, wire,
                    resilience=ResilienceConfig.none()).run()
    _assert_runs_identical(base, armed)
    assert all(l.quarantined_users == 0 for l in armed.logs)


def test_no_fault_parity_dense_fused(problem):
    base = _engine(problem, WirePath(plane="dense")).run()
    armed = _engine(problem, WirePath(plane="dense"),
                    resilience=ResilienceConfig.none()).run()
    _assert_runs_identical(base, armed)


def test_resilience_requires_fused_step():
    full = make_image_classification(n_samples=120, hw=8, n_classes=3,
                                     noise=0.25, seed=0)
    with pytest.raises(ValueError, match="fused"):
        VectorizedFLEngine(
            full, full, partition_iid(full, 4),
            PaperCNNConfig(input_hw=8, n_classes=3),
            MixedResolutionQuantizer(lambda_=0.2, b=10), None, None,
            FLConfig(L=1, T=2, batch_size=8, alpha=0.02, seed=0),
            engine=EngineConfig(wire=WirePath(plane="dense"),
                                fused=False,
                                resilience=ResilienceConfig.none()))


# ------------------------------------------------------- fault axes
def _run_with_plan(problem, wire, plan, guards_on=True):
    res = ResilienceConfig(faults=plan, guards=guards_on)
    return _engine(problem, wire, resilience=res).run()


def test_nan_inf_deltas_quarantined_and_survived(problem):
    plan = FaultPlan(nan_delta_prob=0.4, inf_delta_prob=0.2, seed=11)
    out = _run_with_plan(problem, WirePath(plane="packed"), plan)
    assert sum(l.quarantined_users for l in out.logs) > 0
    for leaf in _leaves(out.params):
        assert np.isfinite(leaf).all()
    assert all(np.isfinite(l.test_acc) for l in out.logs)


def test_dropout_quarantined(problem):
    plan = FaultPlan(dropout_prob=0.5, seed=12)
    out = _run_with_plan(problem, WirePath(plane="packed"), plan)
    assert sum(l.quarantined_users for l in out.logs) > 0
    for leaf in _leaves(out.params):
        assert np.isfinite(leaf).all()


def test_bitflip_detected_only_with_checksum(problem):
    plan = FaultPlan(bitflip_prob=1.0, seed=13)
    checked = _run_with_plan(
        problem, WirePath(plane="packed", checksum=True), plan)
    assert sum(l.quarantined_users for l in checked.logs) > 0
    # without the checksum word the flip is invisible to detection
    unchecked = _run_with_plan(problem, WirePath(plane="packed"), plan)
    assert sum(l.quarantined_users for l in unchecked.logs) == 0
    for leaf in _leaves(unchecked.params):
        assert np.isfinite(leaf).all()


def test_guards_off_blast_radius_dense(problem):
    """The same NaN plan with guards disabled poisons the dense
    aggregate — the measured counterfactual for shipping detection on
    by default.  (The classic quantizer's recon propagates NaN; the
    mixed-res grid arithmetic degrades a NaN row to a zero payload,
    which is why the packed paths can detect on the 8-float header
    alone.)"""
    from repro.core.quantize import make_quantizer
    plan = FaultPlan(nan_delta_prob=0.6, seed=14)
    mk = lambda g: _engine(
        problem, WirePath(plane="dense"),
        resilience=ResilienceConfig(faults=plan, guards=g),
        quantizer=make_quantizer("classic")).run()
    hit = mk(False)
    assert any(not np.isfinite(leaf).all()
               for leaf in _leaves(hit.params))
    saved = mk(True)
    assert sum(l.quarantined_users for l in saved.logs) > 0
    for leaf in _leaves(saved.params):
        assert np.isfinite(leaf).all()


def test_all_users_quarantined_freezes_round(problem):
    """Every payload bad -> the final finite guard freezes the global
    model for the round instead of aggregating nothing."""
    plan = FaultPlan(nan_delta_prob=1.0, seed=15)
    eng = _engine(problem, WirePath(plane="packed"),
                  resilience=ResilienceConfig(faults=plan), T=1)
    before = _leaves(eng.params)
    out = eng.run()
    assert out.logs[0].quarantined_users == K
    for a, b in zip(before, _leaves(out.params)):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------- batched driver + solver
@pytest.fixture(scope="module")
def grid_baseline():
    scn = _tiny("churn-0.7", participation=1.0)
    return run_grid_batched([scn], QUANTIZERS, POWERS, quick=False)


def test_grid_no_fault_parity(grid_baseline):
    scn = _tiny("churn-0.7", participation=1.0)
    armed = run_grid_batched([scn], QUANTIZERS, POWERS, quick=False,
                             resilience=ResilienceConfig.none())
    assert len(armed) == len(grid_baseline) == 1
    a, b = grid_baseline[0], armed[0]
    for la, lb in zip(a.result.logs, b.result.logs):
        np.testing.assert_array_equal(la.bits_per_user, lb.bits_per_user)
        assert la.test_acc == lb.test_acc
        assert la.uplink_latency_s == lb.uplink_latency_s
    assert b.summary["quarantined_users"] == 0.0
    assert b.summary["power_fallbacks"] == 0.0
    assert b.summary.get("resumed_from_round", 0.0) == 0.0


def test_grid_no_fault_parity_async_and_replicated(grid_baseline):
    scn = dataclasses.replace(_tiny("churn-0.7", participation=1.0), async_mode=True,
                              deadline_quantile=0.5,
                              name="async-res-tiny")
    base = run_grid_batched([scn], QUANTIZERS, POWERS, quick=False)
    armed = run_grid_batched([scn], QUANTIZERS, POWERS, quick=False,
                             resilience=ResilienceConfig.none())
    for la, lb in zip(base[0].result.logs, armed[0].result.logs):
        np.testing.assert_array_equal(la.bits_per_user, lb.bits_per_user)
        assert la.test_acc == lb.test_acc
        assert la.uplink_latency_s == lb.uplink_latency_s

    scn_r = _tiny("churn-0.7", participation=1.0, name="repl-res-tiny")
    base_r = run_grid_batched([scn_r], QUANTIZERS, POWERS, quick=False,
                              replicates=2)
    armed_r = run_grid_batched([scn_r], QUANTIZERS, POWERS,
                               quick=False, replicates=2,
                               resilience=ResilienceConfig.none())
    for res_a, res_b in zip(base_r[0].result, armed_r[0].result):
        for la, lb in zip(res_a.logs, res_b.logs):
            np.testing.assert_array_equal(la.bits_per_user,
                                          lb.bits_per_user)
            assert la.test_acc == lb.test_acc
    assert armed_r[0].summary["quarantined_users_ci95"] == 0.0


def test_forced_solver_failure_routes_fallback_chain(grid_baseline):
    plan = FaultPlan(solver_fail_rounds=(1, 2, 3, 4), seed=21)
    scn = _tiny("churn-0.7", participation=1.0)
    out = run_grid_batched([scn], QUANTIZERS, POWERS, quick=False,
                           resilience=ResilienceConfig(faults=plan))
    assert out[0].summary["power_fallbacks"] > 0
    # fallback power control changes latency, never the training
    # trajectory
    for la, lb in zip(grid_baseline[0].result.logs,
                      out[0].result.logs):
        np.testing.assert_array_equal(la.bits_per_user, lb.bits_per_user)
        assert la.test_acc == lb.test_acc
        assert np.isfinite(lb.uplink_latency_s)
        assert lb.power_fallbacks > 0


def test_channel_corruption_rebuilds_transparently(grid_baseline):
    """A corrupted channel-estimate cache is rebuilt from the stored
    realizations, so the solve (and its latency) is unchanged."""
    plan = FaultPlan(channel_corrupt_prob=1.0, seed=22)
    scn = _tiny("churn-0.7", participation=1.0)
    out = run_grid_batched([scn], QUANTIZERS, POWERS, quick=False,
                           resilience=ResilienceConfig(faults=plan))
    for la, lb in zip(grid_baseline[0].result.logs,
                      out[0].result.logs):
        np.testing.assert_allclose(lb.uplink_latency_s,
                                   la.uplink_latency_s, rtol=1e-6)


def test_fault_grid_emits_obs_events_and_report(tmp_path):
    from repro import obs
    from repro.obs.report import load_events, render_report
    path = str(tmp_path / "trace.jsonl")
    plan = FaultPlan(nan_delta_prob=0.4, solver_fail_rounds=(2,),
                     seed=23)
    scn = _tiny("churn-0.7", participation=1.0, name="obs-res-tiny")
    with obs.session(jsonl=path):
        run_grid_batched([scn], QUANTIZERS, POWERS, quick=False,
                         resilience=ResilienceConfig(faults=plan))
    events = load_events(path)
    names = {e.get("name") for e in events}
    assert "resilience.quarantine" in names
    assert "resilience.fallback" in names
    report = render_report(events)
    assert "== resilience ==" in report
    assert "quarantined" in report


# -------------------------------------------- checkpoint/IO hardening
def _tree(x):
    return {"a": np.full((3, 2), x, np.float32),
            "b": np.arange(4, dtype=np.int32) + int(x)}


def test_restore_falls_back_to_newest_valid_checkpoint(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1.0))
    path2 = save_checkpoint(d, 2, _tree(2.0))
    with open(path2, "wb") as f:
        f.write(b"not a zipfile")
    with pytest.warns(UserWarning, match="falling back"):
        tree, step, _ = restore_checkpoint(d, _tree(0.0))
    np.testing.assert_array_equal(tree["a"], _tree(1.0)["a"])
    assert step == 1


def test_restore_raises_when_every_checkpoint_is_corrupt(tmp_path):
    d = str(tmp_path)
    for step in (1, 2):
        path = save_checkpoint(d, step, _tree(step))
        with open(path, "wb") as f:
            f.write(b"\x00" * 16)
    with pytest.raises(Exception):
        restore_checkpoint(d, _tree(0.0))
    assert latest_step(d) == 2      # files exist; restore decides


def test_restore_detects_truncated_archive(tmp_path):
    d = str(tmp_path)
    path = save_checkpoint(d, 3, _tree(3.0))
    raw = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(raw[: len(raw) // 2])
    with pytest.raises(Exception):
        restore_checkpoint(d, _tree(0.0))


def test_metrics_csv_written_atomically(tmp_path):
    path = str(tmp_path / "out" / "metrics.csv")
    rows = [{"scenario": "s", "quantizer": "q", "power": "p",
             "final_acc": 0.5, "quarantined_users": 1.0,
             "power_fallbacks": 2.0}]
    write_metrics_csv(rows, path)
    assert os.path.exists(path)
    leftovers = [f for f in os.listdir(os.path.dirname(path))
                 if f.endswith(".tmp")]
    assert leftovers == []
    header, line = open(path).read().strip().split("\n")
    assert "quarantined_users" in header
    assert "resumed_from_round" in header
    assert line.startswith("s,q,p")


# ------------------------------------------- sweep checkpoint/resume
def test_sweep_checkpoint_roundtrip_skips_completed_rows(tmp_path):
    scn = _tiny("churn-0.7", participation=1.0, T=2, name="ckpt-res-tiny")
    ck = str(tmp_path / "sweep_ckpt")
    first = run_grid_batched([scn], QUANTIZERS, POWERS, quick=False,
                             resilience=ResilienceConfig.none(),
                             checkpoint_dir=ck)
    again = run_grid_batched([scn], QUANTIZERS, POWERS, quick=False,
                             resilience=ResilienceConfig.none(),
                             checkpoint_dir=ck)
    assert len(first) == len(again) == 1
    # second pass replays the ledger: no retraining, same summary
    assert again[0].result is None
    for key, val in first[0].summary.items():
        assert key in again[0].summary
        np.testing.assert_allclose(again[0].summary[key], val,
                                   rtol=1e-12)


@pytest.mark.skipif(os.environ.get("RUN_CHAOS_TESTS") != "1",
                    reason="chaos suite (RUN_CHAOS_TESTS=1): spawns "
                           "and SIGKILLs a sweep subprocess")
def test_kill_minus_nine_and_resume(tmp_path):
    """Preemption drill: the sweep SIGKILLs itself mid-scenario after
    2 checkpointed rounds (kill_after_rounds), then a clean rerun on
    the same checkpoint_dir resumes from the saved round and finishes
    the grid with ``resumed_from_round`` in the CSV."""
    script = textwrap.dedent("""
        import dataclasses, sys
        from repro.resilience import FaultPlan, ResilienceConfig
        from repro.sim import get_scenario, run_grid_batched
        ck, csv, mode = sys.argv[1], sys.argv[2], sys.argv[3]
        scn = dataclasses.replace(
            get_scenario("churn-0.7"), K=4, T=4, n_train=240,
            n_test=60, batch_size=8, L=1, name="chaos-kill-tiny")
        plan = FaultPlan(kill_after_rounds=2) if mode == "kill" \\
            else FaultPlan.none()
        run_grid_batched(
            [scn], {"mixed": ("mixed-resolution",
                              {"lambda_": 0.2, "b": 4})},
            {"ours": "bisection-lp"}, quick=False, out_csv=csv,
            resilience=ResilienceConfig(faults=plan),
            checkpoint_dir=ck)
        print("GRID-DONE")
    """)
    ck = str(tmp_path / "chaos_ckpt")
    csv = str(tmp_path / "chaos.csv")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    kill = subprocess.run([sys.executable, "-c", script, ck, csv,
                           "kill"], env=env, capture_output=True,
                          text=True, timeout=600)
    assert kill.returncode == -9, (kill.returncode, kill.stderr[-2000:])
    assert "GRID-DONE" not in kill.stdout

    resume = subprocess.run([sys.executable, "-c", script, ck, csv,
                             "resume"], env=env, capture_output=True,
                            text=True, timeout=600)
    assert resume.returncode == 0, resume.stderr[-2000:]
    assert "GRID-DONE" in resume.stdout
    header, *lines = open(csv).read().strip().split("\n")
    cols = header.split(",")
    assert "resumed_from_round" in cols
    idx = cols.index("resumed_from_round")
    resumed = [float(line.split(",")[idx]) for line in lines]
    assert len(resumed) == 1 and resumed[0] > 0
