"""Single-process repro.dist coverage: compressor kinds, microbatching,
sharding specs, the engine's user-axis mesh and the MoE shard_map compat
path — everything here runs on the main process's single device (the
8-fake-device checks live in dist_checks.py / test_dist.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.quantize.static_budget import (static_budget_roundtrip,
                                               wire_bits)
from repro.dist import (CompressorConfig, aggregate_delta, budget_k,
                        microbatch, mixed_recon, payload_bits, shard_map)


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def _tree(rng, G=2):
    return {"a": jnp.asarray(rng.standard_normal((G, 300)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((G, 7, 11)), jnp.float32)}


# ----------------------------------------------------------- compressor
def test_aggregate_none_is_exact_fp32_mean():
    rng = np.random.default_rng(0)
    tree = _tree(rng)
    agg, info = aggregate_delta(tree, None, (), CompressorConfig("none"))
    np.testing.assert_array_equal(np.asarray(agg["a"]),
                                  np.asarray(tree["a"]).mean(0))
    np.testing.assert_array_equal(np.asarray(agg["b"]),
                                  np.asarray(tree["b"]).mean(0))
    d = 300 + 7 * 11
    assert info["wire_bits_per_replica"] == 32 * d
    assert agg["b"].shape == (7, 11)


def test_aggregate_mixed_error_bound_and_bits():
    rng = np.random.default_rng(1)
    G, d = 4, 2048
    x = rng.standard_normal((G, d)).astype(np.float32)
    comp = CompressorConfig("mixed", s_budget=0.05, bits=8,
                            exact_topk=True)
    agg, info = aggregate_delta({"w": jnp.asarray(x)}, None, (), comp)
    out = np.asarray(agg["w"])
    true = x.mean(0)
    # every replica's contribution errs by at most ~dw_q (low-res half
    # + grid step); dw_q <= inf-norm, so the mean errs below inf-norm
    assert np.abs(out - true).max() <= np.abs(x).max()
    assert np.corrcoef(out, true)[0, 1] > 0.5
    k = budget_k(d, comp.s_budget)
    assert info["wire_bits_per_replica"] == wire_bits(d, k, comp.bits)
    assert info["wire_bits_per_replica"] < 0.2 * 32 * d


def test_mixed_recon_matches_static_budget_roundtrip():
    """The threshold-based batched roundtrip equals the index-based
    static_budget encode+decode (no rank-k magnitude ties here)."""
    rng = np.random.default_rng(2)
    G, d = 3, 512
    x = rng.standard_normal((G, d)).astype(np.float32)
    comp = CompressorConfig("mixed", s_budget=0.04, bits=4,
                            exact_topk=True)
    recon, dw_q = mixed_recon(jnp.asarray(x), comp)
    k = budget_k(d, comp.s_budget)
    for g in range(G):
        ref = static_budget_roundtrip(jnp.asarray(x[g]), k, comp.bits)
        np.testing.assert_allclose(np.asarray(recon[g]), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
        assert float(dw_q[g]) == float(np.sort(np.abs(x[g]))[-k])


def test_aggregate_manual_mode_matches_stacked():
    """Manual (shard_map) aggregation over a size-1 data axis equals
    the stacked G=1 aggregation — same wire arithmetic, different
    collective convention."""
    rng = np.random.default_rng(3)
    d = 640
    x = rng.standard_normal(d).astype(np.float32)
    mesh = _mesh11()
    for comp in (CompressorConfig("none"),
                 CompressorConfig("mixed", s_budget=0.03, bits=8,
                                  exact_topk=True)):
        def body(v, comp=comp):
            out, _ = aggregate_delta({"w": v}, {"w": P()}, ("data",),
                                     comp)
            return out["w"]
        run = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                        check_vma=False)
        out = np.asarray(jax.jit(run)(jnp.asarray(x)))
        ref, _ = aggregate_delta({"w": jnp.asarray(x[None])}, None, (),
                                 comp)
        np.testing.assert_allclose(out, np.asarray(ref["w"]), rtol=1e-6,
                                   atol=1e-6)


def test_compressor_config_validation():
    with pytest.raises(ValueError):
        CompressorConfig(kind="topk").validate()
    with pytest.raises(ValueError):
        CompressorConfig(kind="mixed", bits=5).validate()
    with pytest.raises(ValueError):
        CompressorConfig(kind="mixed", s_budget=0.0).validate()
    assert payload_bits(100, CompressorConfig("none")) == 3200


# ----------------------------------------------------------- microbatch
def test_microbatch_shapes_and_errors():
    batch = {"tokens": jnp.arange(24).reshape(6, 4)}
    mb = microbatch(batch, 3)
    assert mb["tokens"].shape == (3, 2, 4)
    np.testing.assert_array_equal(np.asarray(mb["tokens"][0]),
                                  np.arange(8).reshape(2, 4))
    with pytest.raises(ValueError):
        microbatch(batch, 4)
    with pytest.raises(ValueError):
        microbatch(batch, 0)


# ------------------------------------------------------------- sharding
def test_param_specs_divisibility_guard():
    from repro.configs import get_config
    from repro.dist import param_shardings, param_specs
    from repro.models import init_model

    cfg = get_config("granite-3-8b").reduced()
    params = jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg))
    mesh = _mesh11()
    specs = param_specs(params, cfg, mesh)
    # model axis of size 1 -> everything replicated
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, P))
    assert all(all(e is None for e in s) for s in flat)
    ns = param_shardings(params, cfg, mesh)
    assert all(isinstance(s, NamedSharding)
               for s in jax.tree_util.tree_leaves(ns))


# ------------------------------------------------- engine mesh sharding
def test_engine_user_axis_mesh_matches_unsharded():
    from repro.core.quantize import MixedResolutionQuantizer
    from repro.data import make_image_classification, partition_iid
    from repro.fl.loop import FLConfig, run_fl
    from repro.sim import EngineConfig
    from repro.sim.engine import VectorizedFLEngine

    data = make_image_classification(n_samples=240, hw=8, channels=1,
                                     n_classes=4, seed=0)
    train = dataclasses.replace(data, x=data.x[:200], y=data.y[:200])
    test = dataclasses.replace(data, x=data.x[200:], y=data.y[200:])
    shards = partition_iid(train, 4, seed=0)
    from repro.configs.paper_cnn import PaperCNNConfig
    cnn = PaperCNNConfig(input_hw=8, channels=1, n_classes=4,
                         conv_filters=4, dense_units=16)
    fl = FLConfig(L=2, T=2, batch_size=16, eval_every=2, seed=0)
    q = MixedResolutionQuantizer(lambda_=0.2, b=8)

    results = {}
    for label, ecfg in (
            ("plain", EngineConfig(fused=True)),
            ("mesh", EngineConfig(fused=True, mesh=_mesh11()))):
        eng = VectorizedFLEngine(train, test, shards, cnn, q, None,
                                 None, fl, engine=ecfg)
        results[label] = eng.run()
    a = jax.tree_util.tree_leaves(results["plain"].params)
    b = jax.tree_util.tree_leaves(results["mesh"].params)
    for la, lb in zip(a, b):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-6, atol=1e-6)
    # run_fl forwards the engine config
    res = run_fl(train, test, shards, cnn, q, None, None, fl,
                 engine=EngineConfig(fused=True, mesh=_mesh11()))
    assert res.rounds_completed == 2


def test_engine_mesh_without_data_axis_warns_and_disables():
    from repro.core.quantize import MixedResolutionQuantizer
    from repro.data import make_image_classification, partition_iid
    from repro.fl.loop import FLConfig
    from repro.sim import EngineConfig
    from repro.sim.engine import VectorizedFLEngine
    from repro.configs.paper_cnn import PaperCNNConfig

    data = make_image_classification(n_samples=80, hw=8, channels=1,
                                     n_classes=2, seed=1)
    shards = partition_iid(data, 2, seed=0)
    cnn = PaperCNNConfig(input_hw=8, channels=1, n_classes=2,
                         conv_filters=4, dense_units=8)
    fl = FLConfig(L=1, T=1, batch_size=8, seed=0)
    mesh = jax.make_mesh((1, 1), ("pod", "model"))  # no "data" axis
    with pytest.warns(UserWarning, match="no 'data' axis"):
        eng = VectorizedFLEngine(data, data, shards, cnn,
                                 MixedResolutionQuantizer(0.2, 8), None,
                                 None, fl,
                                 engine=EngineConfig(fused=True,
                                                     mesh=mesh))
    assert eng._user_sharding is None


# ----------------------------------------------------- MoE compat paths
def _moe_cfg():
    from repro.configs import get_config
    return get_config("qwen2-moe-a2.7b").reduced()


def test_moe_shard_map_paths_run_on_one_device_mesh():
    """The expert-parallel shard_map paths (replicated + a2a) must run
    on this jax version through the compat wrapper."""
    from repro.models.moe import init_moe, moe_apply
    from repro.models.sharding_ctx import logical_axis_rules

    cfg = _moe_cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    mesh = _mesh11()
    rng = np.random.default_rng(0)

    # replicated path: no batch rule, single-token sequence
    x1 = jnp.asarray(rng.standard_normal((2, 1, cfg.d_model)),
                     jnp.float32)
    with logical_axis_rules(mesh, {"expert": "model"}):
        y1, aux1 = jax.jit(lambda p, v: moe_apply(p, v, cfg))(params, x1)
    assert y1.shape == x1.shape and np.isfinite(np.asarray(y1)).all()

    # a2a path: batch rule set, multi-token sequence
    x2 = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)),
                     jnp.float32)
    with logical_axis_rules(mesh, {"expert": "model", "batch": "data"}):
        y2, aux2 = jax.jit(lambda p, v: moe_apply(p, v, cfg))(params, x2)
    assert y2.shape == x2.shape and np.isfinite(np.asarray(y2)).all()
    assert float(aux1) > 0 and float(aux2) > 0
