"""Every fenced python block in README.md and docs/*.md is living
documentation — this suite keeps it that way.

Two tiers:

* **compile** (always on, fast) — every block must at least be valid
  python (`compile()`), including ``# doc-only:`` blocks, which are
  illustrative snippets exempt from execution (they need hardware or
  state the doc page explains, e.g. an 8-device mesh).
* **execute** (the docs CI job: ``RUN_DOC_EXAMPLES=1``) — every
  non-doc-only block runs in a fresh subprocess with
  ``PYTHONPATH=src`` from a temp cwd, exactly as a reader would
  copy-paste it.  Skipped by default so tier-1 stays fast; the
  `test/docs` matrix entry turns it on.
"""
import os
import pathlib
import re
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted([REPO / "README.md"] + list((REPO / "docs").glob("*.md")))
_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)

RUN = os.environ.get("RUN_DOC_EXAMPLES", "") == "1"
# generous: a block may jit-compile the engine from cold
BLOCK_TIMEOUT_S = 900


def _blocks():
    out = []
    for path in DOC_FILES:
        rel = path.relative_to(REPO).as_posix()
        for i, m in enumerate(_FENCE.finditer(path.read_text()), 1):
            code = m.group(1).strip()
            doc_only = code.splitlines()[0].startswith("# doc-only")
            out.append(pytest.param(rel, code, doc_only,
                                    id=f"{rel}:block{i}"))
    return out


BLOCKS = _blocks()


# the four packages whose import surface the docs are written against;
# each must declare an explicit sorted __all__ and every exported name
# must resolve (PR 8's one-wire-path API contract)
PUBLIC_PACKAGES = ("repro.sim", "repro.dist", "repro.kernels",
                   "repro.phy")
_IMPORT = re.compile(
    r"from\s+(repro[\w.]*)\s+import\s+(\([^)]*\)|[^\n]+)")


@pytest.mark.parametrize("mod_name", PUBLIC_PACKAGES)
def test_public_surface_declares_all(mod_name):
    import importlib
    mod = importlib.import_module(mod_name)
    exported = getattr(mod, "__all__", None)
    assert exported, f"{mod_name} must declare an explicit __all__"
    assert list(exported) == sorted(exported), \
        f"{mod_name}.__all__ is not sorted"
    missing = [n for n in exported if not hasattr(mod, n)]
    assert not missing, f"{mod_name}.__all__ names {missing} unresolvable"


def test_doc_imports_go_through_public_all():
    """Every ``from repro.<pkg> import name`` in a documentation block
    must name something the package's __all__ exports — the docs never
    teach private surface."""
    import importlib
    checked = 0
    for param in BLOCKS:
        rel, code, _ = param.values
        for mod_name, names in _IMPORT.findall(code):
            if mod_name not in PUBLIC_PACKAGES:
                continue
            mod = importlib.import_module(mod_name)
            for name in names.strip("()").replace("\n", " ").split(","):
                name = name.strip().split(" as ")[0].strip()
                if not name:
                    continue
                assert name in mod.__all__, (
                    f"{rel} imports {mod_name}.{name}, which is not in "
                    f"{mod_name}.__all__")
                checked += 1
    assert checked > 0, "no public-package imports found in any doc block"


def test_docs_have_examples():
    """The handbook exists and actually carries executable examples."""
    assert (REPO / "docs" / "architecture.md").is_file()
    assert (REPO / "docs" / "wire-format.md").is_file()
    assert (REPO / "docs" / "sweeps.md").is_file()
    files_with_blocks = {p.split(":")[0] for p, *_ in
                         (b.values for b in BLOCKS)}
    assert "README.md" in files_with_blocks
    assert "docs/architecture.md" in files_with_blocks
    assert "docs/sweeps.md" in files_with_blocks


def test_readme_links_handbook():
    text = (REPO / "README.md").read_text()
    for page in ("docs/architecture.md", "docs/wire-format.md",
                 "docs/sweeps.md"):
        assert page in text, f"README does not link {page}"


@pytest.mark.parametrize("rel,code,doc_only", BLOCKS)
def test_block_compiles(rel, code, doc_only):
    compile(code, f"<{rel}>", "exec")


@pytest.mark.parametrize("rel,code,doc_only", BLOCKS)
def test_block_executes(rel, code, doc_only, tmp_path):
    if not RUN:
        pytest.skip("set RUN_DOC_EXAMPLES=1 (the docs CI job) to "
                    "execute documentation examples")
    if doc_only:
        pytest.skip("doc-only block: compile-checked, not executed")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run([sys.executable, "-c", code],
                          cwd=tmp_path, env=env, capture_output=True,
                          text=True, timeout=BLOCK_TIMEOUT_S)
    assert proc.returncode == 0, (
        f"{rel} block failed\n--- stdout ---\n{proc.stdout}"
        f"\n--- stderr ---\n{proc.stderr}")
