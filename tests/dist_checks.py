"""Distributed-runtime correctness checks on an 8-fake-device mesh.

Run as a SCRIPT in its own process (tests/test_dist.py drives it):
the XLA device-count flag must be set before jax initializes, and the
main pytest process must keep seeing 1 device.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.dist import (CompressorConfig, TrainHParams,  # noqa: E402
                        aggregate_delta, batch_shardings,
                        build_decode_step, build_prefill_step,
                        build_train_step, decode_cache_shape,
                        decode_shardings, microbatch, param_shardings,
                        param_specs, shard_map, train_input_shardings)
from repro.launch.inputs import input_specs  # noqa: E402
from repro.models import init_model  # noqa: E402
from repro.models.config import InputShape  # noqa: E402


def small_mesh():
    return jax.make_mesh((2, 4), ("data", "model"))


def check_aggregation_exact_mean():
    """compressor=none must equal the fp32 mean across replicas."""
    mesh = small_mesh()
    x = jnp.arange(2 * 256, dtype=jnp.float32).reshape(2, 256)
    spec = P("data", "model")

    def agg(v):
        return shard_map(
            lambda vl: jax.lax.pmean(vl, ("data",)),
            mesh=mesh, in_specs=spec, out_specs=spec,
            check_vma=False)(v)

    out = jax.jit(agg, in_shardings=NamedSharding(mesh, spec))(x)
    np.testing.assert_allclose(np.asarray(out),
                               np.tile(np.asarray(x).mean(0), (2, 1)))
    print("ok: exact mean baseline")


def check_quantized_aggregation():
    """Quantized aggregate ~ true mean; error within the static-budget
    Lemma-1 bound per replica contribution.  Fully manual over both
    mesh axes: every model shard quantizes its local slice (per-shard
    top-k + packed sign plane + all_gather over data) independently —
    the TPU-native layout of the wire format."""
    mesh = small_mesh()
    rng = np.random.default_rng(0)
    G = 2                                     # data axis = replicas
    d = 4096
    # replica-varying deltas: dim0 sharded over data
    deltas = rng.standard_normal((G, d)).astype(np.float32)
    spikes = rng.choice(d, 40, replace=False)
    deltas[:, spikes] *= 30.0
    x = jnp.asarray(deltas)
    spec_full = P("data", "model")            # replica dim x sharded dim
    comp = CompressorConfig(kind="mixed", s_budget=0.02, bits=8,
                            exact_topk=True)

    def run(v):
        def body(vl):
            # vl: [1, d / model] — this model shard's local slice
            leaf = vl[0]
            out, _ = aggregate_delta(
                {"w": leaf}, {"w": P("model")}, ("data",), comp)
            return out["w"][None]
        return shard_map(body, mesh=mesh, in_specs=spec_full,
                         out_specs=spec_full, check_vma=False)(v)

    out = jax.jit(run, in_shardings=NamedSharding(mesh, spec_full))(x)
    out = np.asarray(out)
    true_mean = deltas.mean(0)
    # every replica row holds the same aggregate
    np.testing.assert_allclose(out[0], out[1], rtol=1e-6)
    # error bounded: per-shard inf-norm * crude bound
    err = np.abs(out[0] - true_mean)
    bound = np.abs(deltas).max() * 0.6
    assert err.max() <= bound, (err.max(), bound)
    # correlation with the true mean must be strong
    c = np.corrcoef(out[0], true_mean)[0, 1]
    assert c > 0.55, c
    print(f"ok: quantized aggregation (corr={c:.3f})")


def check_train_step_runs():
    """Reduced arch, real values, 2 rounds on the 2x4 mesh: loss drops
    or at least stays finite; params stay replica-consistent."""
    mesh = small_mesh()
    cfg = dataclasses.replace(get_config("granite-3-8b").reduced(),
                              ssm_chunk=16)
    shape = InputShape("t", seq_len=32, global_batch=4, kind="train")
    hp = TrainHParams(L_local=2, alpha=0.01,
                      compressor=CompressorConfig(
                          s_budget=0.05, bits=8, exact_topk=True),
                      remat=False)
    params = init_model(jax.random.PRNGKey(0), cfg)
    step = build_train_step(cfg, mesh, shape, hp)
    batch = input_specs(cfg, shape, abstract=False, seed=0)
    batch = microbatch(batch, hp.L_local)
    ps, bs = train_input_shardings(cfg, mesh, shape, params, batch)
    jstep = jax.jit(step, in_shardings=(ps, bs))
    p1, m1 = jstep(params, batch)
    p2, m2 = jstep(p1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) * 1.5
    assert int(m1["wire_bits_per_replica"]) > 0
    leaves = jax.tree_util.tree_leaves(p2)
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in leaves)
    print(f"ok: train step (loss {float(m1['loss']):.3f} -> "
          f"{float(m2['loss']):.3f})")


def check_classic_vs_quantized_bits():
    mesh = small_mesh()
    cfg = get_config("granite-3-8b").reduced()
    shape = InputShape("t", seq_len=32, global_batch=4, kind="train")
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = microbatch(input_specs(cfg, shape, abstract=False), 1)
    outs = {}
    for kind in ("none", "mixed"):
        hp = TrainHParams(compressor=CompressorConfig(
            kind=kind, s_budget=0.01, bits=4, exact_topk=True),
            remat=False)
        step = build_train_step(cfg, mesh, shape, hp)
        ps, bs = train_input_shardings(cfg, mesh, shape, params, batch)
        _, m = jax.jit(step, in_shardings=(ps, bs))(params, batch)
        outs[kind] = int(m["wire_bits_per_replica"])
    assert outs["mixed"] < 0.1 * outs["none"], outs
    print(f"ok: wire bits mixed/classic = "
          f"{outs['mixed'] / outs['none']:.4f}")


def check_moe_train_step():
    mesh = small_mesh()
    cfg = dataclasses.replace(get_config("qwen2-moe-a2.7b").reduced(),
                              ssm_chunk=16)
    shape = InputShape("t", seq_len=32, global_batch=4, kind="train")
    hp = TrainHParams(compressor=CompressorConfig(
        s_budget=0.05, bits=8, exact_topk=True), remat=False)
    params = init_model(jax.random.PRNGKey(1), cfg)
    step = build_train_step(cfg, mesh, shape, hp)
    batch = microbatch(input_specs(cfg, shape, abstract=False), 1)
    ps, bs = train_input_shardings(cfg, mesh, shape, params, batch)
    p1, m1 = jax.jit(step, in_shardings=(ps, bs))(params, batch)
    assert np.isfinite(float(m1["loss"]))
    print(f"ok: MoE train step (loss {float(m1['loss']):.3f})")


def check_prefill_step():
    """Prefill forward on the 2x4 mesh: dense (sequence-parallel
    residual over the model axis) and MoE (expert-parallel all_to_all
    dispatch — serve rules map the expert axis onto 'model')."""
    mesh = small_mesh()
    for arch in ("granite-3-8b", "qwen2-moe-a2.7b"):
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  ssm_chunk=16)
        shape = InputShape("p", seq_len=64, global_batch=4,
                           kind="prefill")
        params = init_model(jax.random.PRNGKey(0), cfg)
        specs = param_specs(params, cfg, mesh)
        flat_specs = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda s: isinstance(s, P))
        assert any("model" in s for s in flat_specs)
        step = build_prefill_step(cfg, mesh, shape)
        batch = input_specs(cfg, shape, abstract=False, seed=0)
        ps = param_shardings(params, cfg, mesh)
        bs = batch_shardings(batch, mesh, shape)
        logits = jax.jit(step, in_shardings=(ps, bs))(params, batch)
        assert logits.shape == (4, 64, cfg.vocab_padded), logits.shape
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        print(f"ok: prefill step {arch}")


def check_decode_step():
    mesh = small_mesh()
    for arch in ("granite-3-8b", "rwkv6-7b", "zamba2-7b"):
        cfg = dataclasses.replace(get_config(arch).reduced(), ssm_chunk=16)
        shape = InputShape("d", seq_len=64, global_batch=4, kind="decode")
        params = init_model(jax.random.PRNGKey(0), cfg)
        serve = build_decode_step(cfg, mesh, shape)
        cache_shape = decode_cache_shape(cfg, shape)
        cache = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), cache_shape)
        ps, cs, ts, isd = decode_shardings(cfg, mesh, shape, params)
        jserve = jax.jit(serve, in_shardings=(ps, cs, ts, isd),
                         out_shardings=(None, cs))
        tokens = jnp.ones((4, 1), jnp.int32)
        logits, new_cache = jserve(params, cache, tokens,
                                   jnp.asarray(5, jnp.int32))
        assert logits.shape == (4, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        print(f"ok: decode step {arch}")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.device_count()
    check_aggregation_exact_mean()
    check_quantized_aggregation()
    check_train_step_runs()
    check_classic_vs_quantized_bits()
    check_moe_train_step()
    check_prefill_step()
    check_decode_step()
    print("ALL DIST CHECKS PASSED")
