"""Optional-hypothesis shim for the property-based tests.

The tier-1 suite must collect and run on a bare container without
``hypothesis`` installed (see requirements-dev.txt for the full dev
environment).  When the module is absent, ``@given``-decorated tests
are skipped with a clear reason instead of breaking collection; the
plain unit tests in the same files still run.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -r "
                       "requirements-dev.txt)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Accepts any strategy constructor call; values are never drawn
        because the test is skip-marked."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
