"""Round-trip coverage for the wire-format bit packing
(core/quantize/packing.py) across code widths and odd lengths, plus
hypothesis property tests over arbitrary contents/lengths (skipped
with a clear reason when hypothesis is not installed)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize.packing import (pack_codes, pack_signs,
                                         unpack_codes, unpack_signs)

from _hypothesis_compat import given, settings, st


@pytest.mark.parametrize("d", [1, 31, 32, 33, 100, 127, 128, 129, 1000])
def test_sign_roundtrip(d):
    rng = np.random.default_rng(d)
    x = rng.standard_normal(d).astype(np.float32)
    x[rng.random(d) < 0.1] = 0.0          # sign(0) must decode as -1
    words = pack_signs(jnp.asarray(x))
    assert words.shape == (-(-d // 32),)
    assert words.dtype == jnp.uint32
    signs = np.asarray(unpack_signs(words, d))
    np.testing.assert_array_equal(signs, np.where(x > 0, 1.0, -1.0))


@pytest.mark.parametrize("b", [2, 4, 8, 16])
@pytest.mark.parametrize("n", [1, 3, 7, 16, 17, 100])
def test_code_roundtrip(b, n):
    rng = np.random.default_rng(b * 1000 + n)
    codes = rng.integers(0, 2 ** b, n).astype(np.uint32)
    words = pack_codes(jnp.asarray(codes), b)
    per = 32 // b
    assert words.shape == (-(-n // per),)
    out = np.asarray(unpack_codes(words, b, n))
    np.testing.assert_array_equal(out, codes)


@pytest.mark.parametrize("b", [0, 3, 5, 7, 24, 33])
def test_code_width_must_divide_32(b):
    """Widths that do not divide 32 would silently mis-split words;
    both pack and unpack must reject them up front."""
    with pytest.raises(ValueError, match="divide 32"):
        pack_codes(jnp.zeros(4, jnp.uint32), b)
    with pytest.raises(ValueError, match="divide 32"):
        unpack_codes(jnp.zeros(1, jnp.uint32), b, 4)


# ------------------------------------------------ edge / degenerate cases
def test_sign_roundtrip_zero_length():
    words = pack_signs(jnp.zeros((0,), jnp.float32))
    assert words.shape == (0,) and words.dtype == jnp.uint32
    assert unpack_signs(words, 0).shape == (0,)


@pytest.mark.parametrize("b", [2, 4, 8, 16])
def test_code_roundtrip_zero_length(b):
    words = pack_codes(jnp.zeros((0,), jnp.uint32), b)
    assert words.shape == (0,) and words.dtype == jnp.uint32
    assert unpack_codes(words, b, 0).shape == (0,)


def test_all_zero_sign_vector_decodes_minus_one():
    """sign(0) transmits bit 0 and must decode as -1 (eq. 7's
    x > 0 convention), for a full word and a ragged tail."""
    for d in (32, 45):
        out = np.asarray(unpack_signs(pack_signs(jnp.zeros(d)), d))
        np.testing.assert_array_equal(out, -np.ones(d, np.float32))


# -------------------------------------------------- hypothesis properties
@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(allow_nan=False, allow_infinity=False,
                          width=32),
                min_size=0, max_size=200))
def test_sign_roundtrip_property(xs):
    """pack/unpack signs is a roundtrip of sign(x > 0) for ANY finite
    float contents at ANY length (word-aligned or not)."""
    x = np.asarray(xs, np.float32)
    words = pack_signs(jnp.asarray(x))
    assert words.shape == (-(-len(xs) // 32),)
    out = np.asarray(unpack_signs(words, len(xs)))
    np.testing.assert_array_equal(out, np.where(x > 0, 1.0, -1.0))


@settings(max_examples=50, deadline=None)
@given(st.sampled_from([2, 4, 8, 16]), st.integers(0, 300),
       st.randoms(use_true_random=False))
def test_code_roundtrip_property(b, n, rnd):
    """pack/unpack codes is a roundtrip for every supported width and
    length, including non-word-aligned tails."""
    codes = np.asarray([rnd.randrange(2 ** b) for _ in range(n)],
                       np.uint32)
    words = pack_codes(jnp.asarray(codes), b)
    per = 32 // b
    assert words.shape == (-(-n // per),)
    assert words.dtype == jnp.uint32
    out = np.asarray(unpack_codes(words, b, n))
    np.testing.assert_array_equal(out, codes)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([2, 4, 8, 16]), st.integers(1, 100))
def test_code_pack_all_zero_property(b, n):
    """All-zero codes pack to all-zero words and roundtrip."""
    words = pack_codes(jnp.zeros(n, jnp.uint32), b)
    assert not np.asarray(words).any()
    np.testing.assert_array_equal(np.asarray(unpack_codes(words, b, n)),
                                  np.zeros(n, np.uint32))


@pytest.mark.parametrize("G,d", [(2, 25600), (3, 4096), (2, 128),
                                 (5, 33000), (8, 262144)])
def test_packed_sign_weighted_sum_blocking(G, d):
    """The stacked G-plane launch must block correctly for every
    (G, d) window — including per-plane rows <= 256 with G*rows not a
    multiple of 256 (regression: AssertionError in signpack)."""
    from repro.kernels.ops import packed_sign_weighted_sum

    rng = np.random.default_rng(G * d)
    x = rng.standard_normal((G, d)).astype(np.float32)
    scales = rng.uniform(0.1, 1.0, G).astype(np.float32)
    out = np.asarray(packed_sign_weighted_sum(jnp.asarray(x),
                                              jnp.asarray(scales)))
    ref = (np.where(x > 0, 1.0, -1.0) * scales[:, None]).sum(0)
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)


def test_pack_signs_matches_pallas_signpack():
    """The jnp reference and the Pallas kernel produce identical
    words on a 128-aligned vector."""
    from repro.kernels.ops import signpack_op

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(4096), jnp.float32)
    np.testing.assert_array_equal(np.asarray(pack_signs(x)),
                                  np.asarray(signpack_op(x)))
