"""Round-trip coverage for the wire-format bit packing
(core/quantize/packing.py) across code widths and odd lengths."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize.packing import (pack_codes, pack_signs,
                                         unpack_codes, unpack_signs)


@pytest.mark.parametrize("d", [1, 31, 32, 33, 100, 127, 128, 129, 1000])
def test_sign_roundtrip(d):
    rng = np.random.default_rng(d)
    x = rng.standard_normal(d).astype(np.float32)
    x[rng.random(d) < 0.1] = 0.0          # sign(0) must decode as -1
    words = pack_signs(jnp.asarray(x))
    assert words.shape == (-(-d // 32),)
    assert words.dtype == jnp.uint32
    signs = np.asarray(unpack_signs(words, d))
    np.testing.assert_array_equal(signs, np.where(x > 0, 1.0, -1.0))


@pytest.mark.parametrize("b", [2, 4, 8, 16])
@pytest.mark.parametrize("n", [1, 3, 7, 16, 17, 100])
def test_code_roundtrip(b, n):
    rng = np.random.default_rng(b * 1000 + n)
    codes = rng.integers(0, 2 ** b, n).astype(np.uint32)
    words = pack_codes(jnp.asarray(codes), b)
    per = 32 // b
    assert words.shape == (-(-n // per),)
    out = np.asarray(unpack_codes(words, b, n))
    np.testing.assert_array_equal(out, codes)


def test_code_width_must_divide_32():
    with pytest.raises(ValueError):
        pack_codes(jnp.zeros(4, jnp.uint32), 5)


@pytest.mark.parametrize("G,d", [(2, 25600), (3, 4096), (2, 128),
                                 (5, 33000), (8, 262144)])
def test_packed_sign_weighted_sum_blocking(G, d):
    """The stacked G-plane launch must block correctly for every
    (G, d) window — including per-plane rows <= 256 with G*rows not a
    multiple of 256 (regression: AssertionError in signpack)."""
    from repro.kernels.ops import packed_sign_weighted_sum

    rng = np.random.default_rng(G * d)
    x = rng.standard_normal((G, d)).astype(np.float32)
    scales = rng.uniform(0.1, 1.0, G).astype(np.float32)
    out = np.asarray(packed_sign_weighted_sum(jnp.asarray(x),
                                              jnp.asarray(scales)))
    ref = (np.where(x > 0, 1.0, -1.0) * scales[:, None]).sum(0)
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)


def test_pack_signs_matches_pallas_signpack():
    """The jnp reference and the Pallas kernel produce identical
    words on a 128-aligned vector."""
    from repro.kernels.ops import signpack_op

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(4096), jnp.float32)
    np.testing.assert_array_equal(np.asarray(pack_signs(x)),
                                  np.asarray(signpack_op(x)))
