"""Optimizer / data-pipeline / checkpoint substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import (latest_step, restore_checkpoint,
                                 save_checkpoint)
from repro.data import TokenBatcher, make_token_stream, prefetch
from repro.optim import adagrad, adam, apply_updates, make_optimizer, sgd


def quad_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss, target


@pytest.mark.parametrize("name,kwargs", [
    ("adagrad", {"alpha": 0.5}),
    ("adam", {"lr": 0.1}),
    ("sgd", {"lr": 0.1, "momentum": 0.9}),
])
def test_optimizers_converge(name, kwargs):
    params, loss, target = quad_problem()
    opt = make_optimizer(name, **kwargs)
    state = opt.init(params)
    for _ in range(300):
        grads = jax.grad(loss)(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    np.testing.assert_allclose(params["w"], target, atol=0.05)


def test_adagrad_matches_eq2():
    """One AdaGrad step == eq. (2) by hand."""
    opt = adagrad(alpha=0.1, eps=1e-8)
    params = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -1.0])}
    state = opt.init(params)
    upd, state = opt.update(g, state, params)
    expect = -0.1 * g["w"] / jnp.sqrt(g["w"] ** 2 + 1e-8)
    np.testing.assert_allclose(upd["w"], expect, rtol=1e-6)
    np.testing.assert_allclose(state["w"], g["w"] ** 2)


def test_token_batcher_shapes_and_determinism():
    stream = make_token_stream(5000, vocab=100, seed=0)
    assert stream.min() >= 0 and stream.max() < 100
    b1 = list(TokenBatcher(stream, batch=4, seq=32, seed=1))
    b2 = list(TokenBatcher(stream, batch=4, seq=32, seed=1))
    assert len(b1) > 0
    for a, b in zip(b1, b2):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert a["tokens"].shape == (4, 32)


def test_prefetch_preserves_order():
    items = list(range(20))
    assert list(prefetch(iter(items), depth=3)) == items


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(5, dtype=jnp.float32),
            "nested": {"b": jnp.ones((2, 3))}}
    save_checkpoint(d, 10, tree, metadata={"note": "x"})
    save_checkpoint(d, 20, tree)
    assert latest_step(d) == 20
    out, step, meta = restore_checkpoint(d, tree, step=10)
    assert step == 10 and meta == {"note": "x"}
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["nested"]["b"], tree["nested"]["b"])


def test_checkpoint_retention(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.zeros(2)}
    for s in range(6):
        save_checkpoint(d, s, tree, keep=3)
    files = [f for f in os.listdir(d) if f.endswith(".npz")]
    assert len(files) == 3
    assert latest_step(d) == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"a": jnp.zeros(4)})
