"""Pallas kernel tests: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.ops import (flash_decode_op, sign_dequant_reduce_op,
                               signpack_op)
from repro.kernels.quant_pack import sign_dequant_reduce, signpack
from repro.kernels.ref import (flash_decode_ref, sign_dequant_reduce_ref,
                               signpack_ref)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


# ------------------------------------------------------------- signpack
@pytest.mark.parametrize("W", [4, 256, 1024])
def test_signpack_matches_ref(W):
    x = rand(0, (W, 128))
    got = signpack(x, interpret=True, block_rows=min(256, W))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(signpack_ref(x)))


def test_signpack_op_flat_roundtrip():
    d = 128 * 64
    x = rand(1, (d,))
    words = signpack_op(x)
    assert words.shape == (d // 32,) and words.dtype == jnp.uint32
    # consistency with core packing (wire-format compatibility)
    from repro.core.quantize import pack_signs
    np.testing.assert_array_equal(np.asarray(words),
                                  np.asarray(pack_signs(x)))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([1, 2, 8]))
def test_signpack_property(seed, wmul):
    W = 8 * wmul
    x = rand(seed, (W, 128))
    got = signpack(x, interpret=True, block_rows=W)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(signpack_ref(x)))


# -------------------------------------------------- sign dequant+reduce
@pytest.mark.parametrize("G,W", [(1, 8), (4, 256), (16, 64)])
def test_sign_dequant_reduce_matches_ref(G, W):
    rng = np.random.default_rng(0)
    words = jnp.asarray(rng.integers(0, 2 ** 32, (G, W, 4), dtype=np.uint64)
                        .astype(np.uint32))
    scales = jnp.asarray(rng.uniform(0.1, 2.0, G), jnp.float32)
    got = sign_dequant_reduce(words, scales, interpret=True,
                              block_rows=min(256, W))
    want = sign_dequant_reduce_ref(words, scales)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)


def test_sign_pack_dequant_end_to_end():
    """pack(x) -> dequant == sign(x) * scale (the aggregation fast path)."""
    d = 128 * 32
    x = rand(2, (d,))
    words = signpack_op(x)
    out = sign_dequant_reduce_op(words[None], jnp.asarray([0.5]))
    expect = np.where(np.asarray(x) > 0, 0.5, -0.5)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6)


# ----------------------------------------------------------- flash decode
@pytest.mark.parametrize("B,Hkv,G,S,D,Dv,dtype", [
    (1, 1, 1, 512, 64, 64, jnp.float32),
    (2, 4, 2, 1024, 128, 128, jnp.float32),
    (2, 2, 8, 2048, 64, 64, jnp.bfloat16),
    (1, 8, 5, 512, 128, 64, jnp.float32),   # uneven group, Dv != D
])
def test_flash_decode_matches_ref(B, Hkv, G, S, D, Dv, dtype):
    q = rand(0, (B, Hkv, G, D), dtype)
    k = rand(1, (B, Hkv, S, D), dtype)
    v = rand(2, (B, Hkv, S, Dv), dtype)
    length = jnp.asarray(S - 17, jnp.int32)
    from repro.kernels.flash_decode import flash_decode
    got = flash_decode(q, k, v, length, kv_block=256, interpret=True)
    want = flash_decode_ref(q, k, v, length)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_decode_short_length():
    """Masking: only the first 3 cache entries count."""
    B, Hkv, G, S, D = 1, 1, 1, 512, 64
    q = rand(0, (B, Hkv, G, D))
    k = rand(1, (B, Hkv, S, D))
    v = rand(2, (B, Hkv, S, D))
    from repro.kernels.flash_decode import flash_decode
    got = flash_decode(q, k, v, jnp.asarray(3, jnp.int32),
                       kv_block=128, interpret=True)
    want = flash_decode_ref(q, k, v, jnp.asarray(3, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_op_gqa_layout():
    """ops wrapper: [B,H,D] x [B,S,Hkv,D] layout equals oracle."""
    B, H, Hkv, S, D = 2, 8, 2, 1024, 64
    q = rand(0, (B, H, D))
    k = rand(1, (B, S, Hkv, D))
    v = rand(2, (B, S, Hkv, D))
    length = jnp.asarray(S, jnp.int32)
    got = flash_decode_op(q, k, v, length, kv_block=256)
    want = flash_decode_ref(q.reshape(B, Hkv, H // Hkv, D),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), length)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want.reshape(B, H, D)),
                               rtol=1e-5, atol=1e-5)
