"""Validate the dry-run sweep artifacts (deliverable e).

The sweep itself runs out-of-band (hours of XLA compiles for 512
placeholder devices): ``python -m repro.launch.dryrun --arch all
--shape all --mesh single|multi``.  These tests check the recorded
results: every (arch x shape x mesh) must have compiled OK or be a
documented skip; skips are exactly the DESIGN.md §Arch-applicability
set; roofline inputs are sane.
"""
import glob
import json
import os

import pytest

from repro.configs import ARCH_IDS
from repro.models.config import INPUT_SHAPES

RUNS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "runs", "dryrun")

EXPECTED_SKIPS = {("whisper-base", "long_500k")}


def _load(mesh):
    out = {}
    for p in glob.glob(os.path.join(RUNS, f"*__{mesh}__*.json")):
        with open(p) as f:
            r = json.load(f)
        out[(r["arch"], r["shape"])] = r
    return out


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_matrix_complete(mesh):
    results = _load(mesh)
    if not results:
        pytest.skip(f"no {mesh} dry-run artifacts; run the sweep first")
    missing, errors = [], []
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            r = results.get((arch, shape))
            if r is None:
                missing.append((arch, shape))
            elif r["status"] == "error":
                errors.append((arch, shape, r.get("error", "")[:80]))
            elif r["status"] == "skipped":
                assert (arch, shape) in EXPECTED_SKIPS, (arch, shape)
    assert not errors, errors
    if missing:
        pytest.skip(f"sweep incomplete for {mesh}: {len(missing)} missing")


def test_single_pod_roofline_inputs_sane():
    results = _load("single")
    if not results:
        pytest.skip("no artifacts")
    for (arch, shape), r in results.items():
        if r["status"] != "ok":
            continue
        assert r["flops"] > 0, (arch, shape)
        assert r["hbm_bytes"] > 0
        assert r["n_devices"] == 256
        assert r["model_flops"] > 0
        # train/prefill move more than decode
        if shape == "train_4k":
            assert r["collective_bytes"] > 0


def test_skips_documented():
    results = _load("single")
    if not results:
        pytest.skip("no artifacts")
    skips = {(a, s) for (a, s), r in results.items()
             if r["status"] == "skipped"}
    assert skips <= EXPECTED_SKIPS
