"""numpy-vs-JAX parity for the batched physical layer (repro.phy).

Runs in BOTH precisions — CI executes this file twice, with and
without JAX_ENABLE_X64=1 — with per-component tolerances from the
contract in DESIGN.md section 7:

* channel bundle + rate evaluation + bisection-LP + Dinkelbach:
  trajectory-exact ports; x64 parity is ~1e-13 (asserted at 1e-5),
  f32 parity is documented looser (the numpy reference stays f64).
* max-sum-rate: the reference's forward-difference ascent divides ulp
  noise by h=1e-6, so long trajectories are chaotic — ulp-level
  arithmetic differences (BLAS vs XLA summation order) select
  different local optima.  Parity is asserted on short trajectories
  (exact-port check) and on achieved objective quality at full
  settings.  In f32 the FD difference is below the objective's ulp, so
  the solvers default to autodiff gradients (grad_mode="auto") and the
  f32 leg checks solution quality, not trajectories.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.channel import CFmMIMOConfig, make_channel
from repro.core.power import (BisectionLPPowerControl,
                              DinkelbachPowerControl,
                              MaxSumRatePowerControl,
                              equalizing_target_latency, eta_upper_bound,
                              rate_aware_fractions)
from repro.phy import (bisection_solve, bundle_from_realizations,
                       dinkelbach_solve, equalizing_target_latency_batch,
                       eta_upper_bound_batch, make_channel_batch,
                       maxsum_solve, rate_aware_fractions_batch)

X64 = bool(jax.config.jax_enable_x64)
N_REAL = 100                         # random channel realizations
CFG = CFmMIMOConfig(K=10, M=9)

# tolerance contract (DESIGN.md section 7): x64 / f32
TOL_BUNDLE = 1e-12 if X64 else 1e-5
TOL_RATES_EVAL = 1e-10 if X64 else 1e-2
TOL_BISECTION = 1e-5 if X64 else 1e-3
TOL_DINKELBACH = 1e-5 if X64 else 1e-2
TOL_MAXSUM_SHORT = 1e-5              # x64 only (fd exact-port regime)
TOL_OBJ_QUALITY = 5e-2               # achieved objective vs reference


@pytest.fixture(scope="module")
def realizations():
    return [make_channel(CFG, seed=s) for s in range(N_REAL)]


@pytest.fixture(scope="module")
def bundle(realizations):
    return bundle_from_realizations(realizations)


@pytest.fixture(scope="module")
def payloads():
    rng = np.random.default_rng(1)
    return rng.uniform(1e5, 2e6, (N_REAL, CFG.K))


def _rel(a, b, floor=1.0):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return np.max(np.abs(a - b) / np.maximum(np.abs(b), floor))


# ------------------------------------------------------------- channel
def test_bundle_matches_numpy(realizations):
    """make_channel_batch (host geometry + device eq. 5 math) equals
    the per-realization numpy bundles."""
    cb = make_channel_batch(CFG, list(range(N_REAL)))
    for f in ("A_bar", "B_bar", "B_tilde", "I_M"):
        ref = np.stack([getattr(c, f) for c in realizations])
        got = np.asarray(getattr(cb, f), np.float64)
        rel = np.abs(got - ref) / np.maximum(np.abs(ref), 1e-300)
        assert rel.max() < TOL_BUNDLE, (f, rel.max())


def test_rates_evaluation_matches_numpy(realizations, bundle):
    rng = np.random.default_rng(2)
    p = rng.uniform(0.05, 1.0, (N_REAL, CFG.K))
    ref = np.stack([c.rates(p[i]) for i, c in enumerate(realizations)])
    got = np.asarray(bundle.rates(p), np.float64)
    assert np.max(np.abs(got - ref) / ref) < TOL_RATES_EVAL


def test_eta_upper_bound_matches_numpy(realizations, bundle, payloads):
    ref = np.array([eta_upper_bound(c, payloads[i])
                    for i, c in enumerate(realizations)])
    got = np.asarray(eta_upper_bound_batch(bundle, payloads), np.float64)
    assert np.max(np.abs(got - ref) / ref) < TOL_RATES_EVAL


# ----------------------------------------------------------- bisection
def test_bisection_matches_numpy(realizations, bundle, payloads):
    """Batched projected-bisection (linear-solve feasibility) vs the
    scipy-LP reference: same bisection decisions, same min-sum-power
    vector — the headline rates-within-1e-5 x64 criterion."""
    sol = bisection_solve(bundle, payloads)
    ref = [BisectionLPPowerControl().solve(c, payloads[i])
           for i, c in enumerate(realizations)]
    ref_rates = np.stack([r.rates for r in ref])
    ref_eta = np.array([r.info["eta"] for r in ref])
    assert np.max(np.abs(np.asarray(sol.rates, np.float64) - ref_rates)
                  / ref_rates) < TOL_BISECTION
    assert _rel(sol.info["eta"], ref_eta, floor=1e-12) < TOL_BISECTION
    assert _rel(sol.straggler_latency,
                [r.straggler_latency for r in ref],
                floor=1e-12) < TOL_BISECTION


# ---------------------------------------------------------- dinkelbach
def test_dinkelbach_matches_numpy(realizations, bundle, payloads):
    """fd mode replays the reference trajectory (which never escapes
    the all-ones clip — the FD gradient is exactly zero there);
    rates match to roundoff in x64."""
    if not X64:
        pytest.skip("fd gradients are sub-ulp in f32; the f32 leg "
                    "checks auto-mode quality below")
    sol = dinkelbach_solve(bundle, payloads, grad_mode="fd")
    ref = np.stack([DinkelbachPowerControl().solve(c, payloads[i]).rates
                    for i, c in enumerate(realizations)])
    assert np.max(np.abs(np.asarray(sol.rates, np.float64) - ref)
                  / ref) < TOL_DINKELBACH


def test_dinkelbach_auto_no_worse_than_reference(realizations, bundle,
                                                 payloads):
    """auto (jax.grad) mode genuinely optimizes — achieved EE is never
    materially below the reference's."""
    sol = dinkelbach_solve(bundle, payloads, grad_mode="auto")
    ref = np.array([DinkelbachPowerControl().solve(
        c, payloads[i]).info["energy_efficiency"]
        for i, c in enumerate(realizations)])
    got = np.asarray(sol.info["energy_efficiency"], np.float64)
    assert np.all(got >= ref * (1.0 - TOL_OBJ_QUALITY))


# ------------------------------------------------------- max-sum-rate
def test_maxsum_short_trajectory_matches_numpy(realizations, bundle,
                                               payloads):
    """Exact-port check: before FD-noise amplification bifurcates the
    non-convex ascent, the batched trajectory tracks numpy's."""
    if not X64:
        pytest.skip("fd gradients are sub-ulp in f32")
    sol = maxsum_solve(bundle, payloads, iters=5, restarts=2,
                       grad_mode="fd")
    ref = np.stack([MaxSumRatePowerControl(iters=5, restarts=2).solve(
        c, payloads[i]).rates for i, c in enumerate(realizations)])
    # absolute floor 1 bit/s: the ascent may switch a user fully off
    assert _rel(sol.rates, ref) < TOL_MAXSUM_SHORT


def test_maxsum_full_quality_vs_numpy(realizations, bundle, payloads):
    """Full-setting runs bifurcate (documented chaos); the achieved
    sum-rate objective must stay within a few percent of the
    reference's local optimum."""
    kwargs = {"grad_mode": "fd"} if X64 else {}
    sol = maxsum_solve(bundle, payloads, **kwargs)
    ref = np.array([MaxSumRatePowerControl().solve(
        c, payloads[i]).info["sum_rate"]
        for i, c in enumerate(realizations)])
    got = np.asarray(sol.info["sum_rate"], np.float64)
    assert np.all(got >= ref * (1.0 - TOL_OBJ_QUALITY))


# ------------------------------------------------- masked == subchannel
def _subchannel(chan, idx):
    cfg = dataclasses.replace(chan.cfg, K=len(idx))
    return dataclasses.replace(
        chan, cfg=cfg, beta=chan.beta[:, idx], pilot=chan.pilot[idx],
        gamma=chan.gamma[:, idx], A_bar=chan.A_bar[idx],
        B_bar=chan.B_bar[idx], B_tilde=chan.B_tilde[np.ix_(idx, idx)],
        I_M=chan.I_M[idx])


def test_masked_bisection_matches_subchannel(realizations, bundle,
                                             payloads):
    """The solvers' mask argument implements the engine's sub-channel
    churn semantics: absent users get no power, contribute no
    interference and never straggle."""
    n = 30
    rng = np.random.default_rng(3)
    mask = (rng.random((n, CFG.K)) < 0.6).astype(np.float64)
    mask[mask.sum(axis=1) == 0, 0] = 1.0
    bits = np.where(mask > 0, payloads[:n], 1.0)
    sub = bundle_from_realizations(realizations[:n])
    sol = bisection_solve(sub, bits, mask=mask)
    assert np.all(np.asarray(sol.p)[mask == 0] == 0.0)
    assert np.all(np.asarray(sol.latencies)[mask == 0] == 0.0)
    for i in range(n):
        idx = np.flatnonzero(mask[i])
        ref = BisectionLPPowerControl().solve(
            _subchannel(realizations[i], idx), bits[i][idx])
        got = float(np.asarray(sol.straggler_latency)[i])
        assert abs(got - ref.straggler_latency) \
            / ref.straggler_latency < TOL_BISECTION


# ------------------------------------------------------------ bitalloc
def test_bitalloc_matches_numpy():
    rng = np.random.default_rng(4)
    rates = rng.uniform(1e5, 1e7, (16, 12))
    d, b = 100_000, 10
    ref_ell = np.array([equalizing_target_latency(r, d, b, 0.01)
                        for r in rates])
    got_ell = np.asarray(
        equalizing_target_latency_batch(rates, d, b, 0.01), np.float64)
    np.testing.assert_allclose(got_ell, ref_ell,
                               rtol=1e-12 if X64 else 1e-5)
    ref_s = np.stack([rate_aware_fractions(r, d, b, ref_ell[i],
                                           s_min=0.01)
                      for i, r in enumerate(rates)])
    got_s = np.asarray(rate_aware_fractions_batch(
        rates, d, b, got_ell[:, None], s_min=0.01), np.float64)
    np.testing.assert_allclose(got_s, ref_s,
                               atol=1e-10 if X64 else 1e-4)
