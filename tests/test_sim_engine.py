"""repro.sim engine tests: bit-for-bit equivalence with the sequential
reference loop, fused/signplane consistency, scenario registry smoke."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.paper_cnn import PaperCNNConfig
from repro.core.channel import CFmMIMOConfig, make_channel
from repro.core.power import BisectionLPPowerControl
from repro.core.quantize import (ClassicQuantizer, LAQQuantizer,
                                 MixedResolutionQuantizer, TopQQuantizer)
from repro.data import (make_image_classification, partition_iid,
                        partition_powerlaw)
from repro.fl import FLConfig, run_fl, run_fl_sequential
from repro.sim import (SCENARIOS, EngineConfig, Scenario,
                       VectorizedFLEngine, build_problem, get_scenario,
                       list_scenarios, run_cell, summarize_logs)


@pytest.fixture(scope="module")
def problem():
    full = make_image_classification(n_samples=900, hw=16, n_classes=4,
                                     noise=0.25, seed=0)
    train = dataclasses.replace(full, x=full.x[:700], y=full.y[:700])
    test = dataclasses.replace(full, x=full.x[700:], y=full.y[700:])
    cfg = PaperCNNConfig(input_hw=16, n_classes=4)
    return train, test, cfg


def _leaves(params):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(params)]


# ------------------------------------------------- engine == sequential
@pytest.mark.parametrize("quantizer_factory", [
    lambda: MixedResolutionQuantizer(lambda_=0.2, b=10),
    lambda: LAQQuantizer(b=4, xi=0.5),          # stateful
    lambda: TopQQuantizer(q=0.01),
], ids=["mixed-resolution", "laq", "top-q"])
def test_engine_matches_sequential_bit_for_bit(problem, quantizer_factory):
    """run_fl (vectorized engine, exact mode) must reproduce the seed's
    sequential loop bit-for-bit: params, bits, latency, accuracy."""
    train, test, cfg = problem
    K = 6
    shards = partition_iid(train, K)
    chan = make_channel(CFmMIMOConfig(K=K), seed=0)
    fl = FLConfig(L=3, T=4, batch_size=24, alpha=0.02, eval_every=2,
                  seed=0)
    power = BisectionLPPowerControl()
    seq = run_fl_sequential(train, test, shards, cfg, quantizer_factory(),
                            power, chan, fl)
    eng = run_fl(train, test, shards, cfg, quantizer_factory(),
                 power, chan, fl)

    assert len(seq.logs) == len(eng.logs)
    for ls, le in zip(seq.logs, eng.logs):
        np.testing.assert_array_equal(ls.bits_per_user, le.bits_per_user)
        assert ls.uplink_latency_s == le.uplink_latency_s
        assert ls.cum_latency_s == le.cum_latency_s
        assert ls.mean_s == le.mean_s
        assert ls.test_acc == le.test_acc
    for a, b in zip(_leaves(seq.params), _leaves(eng.params)):
        np.testing.assert_array_equal(a, b)


def test_vmap_batching_matches_sequential_bit_for_bit(problem):
    """The accelerator-oriented vmap local-batching path is also
    bitwise identical to the sequential per-user jit."""
    train, test, cfg = problem
    K = 4
    shards = partition_iid(train, K)
    fl = FLConfig(L=2, T=3, batch_size=16, alpha=0.02, eval_every=3,
                  seed=0)
    q = MixedResolutionQuantizer(lambda_=0.2, b=10)
    seq = run_fl_sequential(train, test, shards, cfg, q, None, None, fl)
    eng = VectorizedFLEngine(
        train, test, shards, cfg, q, None, None, fl,
        engine=EngineConfig(local_batching="vmap")).run()
    for ls, le in zip(seq.logs, eng.logs):
        np.testing.assert_array_equal(ls.bits_per_user, le.bits_per_user)
        assert ls.test_acc == le.test_acc
    for a, b in zip(_leaves(seq.params), _leaves(eng.params)):
        np.testing.assert_array_equal(a, b)


def test_ragged_shards_fall_back_to_sequential(problem):
    """When a shard is smaller than batch_size the engine's uniform
    [K, L, b] stacking cannot replay the per-user batch clamp, so
    run_fl must fall back to the sequential loop bit-for-bit."""
    train, test, cfg = problem
    shards = partition_iid(train, 4)
    shards[2] = shards[2][:10]              # smaller than batch_size
    fl = FLConfig(L=2, T=2, batch_size=16, alpha=0.02, eval_every=2,
                  seed=0)
    q = MixedResolutionQuantizer(lambda_=0.2, b=10)
    seq = run_fl_sequential(train, test, shards, cfg, q, None, None, fl)
    via_run_fl = run_fl(train, test, shards, cfg, q, None, None, fl)
    for ls, le in zip(seq.logs, via_run_fl.logs):
        np.testing.assert_array_equal(ls.bits_per_user, le.bits_per_user)
    for a, b in zip(_leaves(seq.params), _leaves(via_run_fl.params)):
        np.testing.assert_array_equal(a, b)


def test_fused_step_matches_exact_to_roundoff(problem):
    """The single-jit fused step equals the exact path up to XLA's
    cross-op fusion (FMA contraction): float32 roundoff, not drift."""
    train, test, cfg = problem
    K = 6
    shards = partition_iid(train, K)
    fl = FLConfig(L=2, T=3, batch_size=16, alpha=0.02, eval_every=3,
                  seed=0)
    q = MixedResolutionQuantizer(lambda_=0.2, b=10)
    exact = VectorizedFLEngine(train, test, shards, cfg, q, None, None,
                               fl).run()
    fused = VectorizedFLEngine(train, test, shards, cfg, q, None, None,
                               fl, engine=EngineConfig(fused=True)).run()
    # round-1 payloads agree to float32 roundoff of the s fraction
    np.testing.assert_allclose(exact.logs[0].bits_per_user,
                               fused.logs[0].bits_per_user, rtol=1e-5)
    for a, b in zip(_leaves(exact.params), _leaves(fused.params)):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)


def test_signplane_aggregation_matches_dense(problem):
    """The Pallas wire-format path (signpack -> sign_dequant_reduce +
    high-res correction) reconstructs the same aggregate as the dense
    weighted sum, up to float32 roundoff."""
    train, test, cfg = problem
    K = 6
    shards = partition_iid(train, K)
    fl = FLConfig(L=2, T=2, batch_size=16, alpha=0.02, eval_every=2,
                  seed=0)
    q = MixedResolutionQuantizer(lambda_=0.2, b=10)
    dense = VectorizedFLEngine(
        train, test, shards, cfg, q, None, None, fl,
        engine=EngineConfig(fused=True)).run()
    wire = VectorizedFLEngine(
        train, test, shards, cfg, q, None, None, fl,
        engine=EngineConfig(aggregation="signplane")).run()
    np.testing.assert_allclose(dense.logs[0].bits_per_user,
                               wire.logs[0].bits_per_user, rtol=1e-5)
    for a, b in zip(_leaves(dense.params), _leaves(wire.params)):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)


def test_wire_aggregation_matches_dense(problem):
    """The fully fused quantize-to-wire path (mixed-res kernel suite:
    streaming reductions -> packed planes -> fused dequant+reduce, no
    dense recon) reproduces the fused dense path: payload bits
    bit-for-bit (exact integer dbar), params to float32 roundoff."""
    train, test, cfg = problem
    K = 6
    shards = partition_iid(train, K)
    fl = FLConfig(L=2, T=2, batch_size=16, alpha=0.02, eval_every=2,
                  seed=0)
    q = MixedResolutionQuantizer(lambda_=0.2, b=10)
    dense = VectorizedFLEngine(
        train, test, shards, cfg, q, None, None, fl,
        engine=EngineConfig(fused=True)).run()
    wire = VectorizedFLEngine(
        train, test, shards, cfg, q, None, None, fl,
        engine=EngineConfig(aggregation="wire")).run()
    np.testing.assert_array_equal(dense.logs[0].bits_per_user,
                                  wire.logs[0].bits_per_user)
    for a, b in zip(_leaves(dense.params), _leaves(wire.params)):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)


def test_fused_step_donation_reusable_and_warning_free(problem):
    """The fused step donates its params/qstate carries; the engine
    must stay re-runnable (start_run hands it private copies) and the
    donation must be clean — no 'donated buffer' XLA warnings."""
    import warnings as _warnings

    train, test, cfg = problem
    shards = partition_iid(train, 4)
    fl = FLConfig(L=1, T=2, batch_size=8, eval_every=2, seed=0)
    eng = VectorizedFLEngine(
        train, test, shards, cfg, MixedResolutionQuantizer(0.2, 10),
        None, None, fl, engine=EngineConfig(fused=True))
    with _warnings.catch_warnings(record=True) as caught:
        _warnings.simplefilter("always")
        first = eng.run()
        second = eng.run()          # donated inputs must not leak back
    donated = [str(w.message) for w in caught
               if "donat" in str(w.message).lower()]
    assert donated == [], donated
    for a, b in zip(_leaves(first.params), _leaves(second.params)):
        np.testing.assert_array_equal(a, b)


def test_signplane_rejects_non_mixed_quantizer(problem):
    train, test, cfg = problem
    shards = partition_iid(train, 4)
    fl = FLConfig(L=1, T=1, batch_size=8, seed=0)
    with pytest.raises(ValueError, match="signplane"):
        VectorizedFLEngine(train, test, shards, cfg, ClassicQuantizer(),
                           None, None, fl,
                           engine=EngineConfig(aggregation="signplane"))


# ----------------------------------------------------------- scenarios
def _shrink(scn: Scenario) -> Scenario:
    """Tiny test-speed variant of a scenario (smaller than quick)."""
    return dataclasses.replace(
        scn, K=min(scn.K, 4), T=2, n_train=240, n_test=60, batch_size=8,
        L=1)


def test_scenario_registry_contents():
    names = list_scenarios()
    # paper operating points + the new workloads + the K/M grid
    for expected in ["paper-table2", "paper-table3", "churn-0.7",
                     "monte-carlo-channel", "hetero-data",
                     "signplane-wire", "fused-wire", "grid-K20-M16"]:
        assert expected in names, expected
    with pytest.raises(KeyError):
        get_scenario("does-not-exist")


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_smoke(name):
    """Every registered scenario builds and completes rounds end-to-end
    on the engine (shrunk to test size)."""
    scn = _shrink(get_scenario(name))
    res = run_cell(scn, ("mixed-resolution", {"lambda_": 0.2, "b": 4}),
                   power=None, quick=False)
    assert res.result.rounds_completed == scn.T
    summary = res.summary
    assert np.isfinite(summary["mean_bits_per_user"])
    assert summary["rounds"] == scn.T
    assert 0.0 <= summary["best_acc"] <= 1.0


def test_churn_masks_inactive_users(problem):
    """Partial participation: inactive users transmit 0 bits and the
    model still trains on the active subset."""
    train, test, cfg = problem
    K = 8
    shards = partition_iid(train, K)
    fl = FLConfig(L=1, T=6, batch_size=8, alpha=0.02, eval_every=6,
                  seed=0)
    q = MixedResolutionQuantizer(lambda_=0.2, b=8)
    res = VectorizedFLEngine(
        train, test, shards, cfg, q, None, None, fl,
        engine=EngineConfig(fused=True, participation=0.5)).run()
    zero_rounds = sum(1 for l in res.logs if (l.bits_per_user == 0).any())
    assert zero_rounds > 0                 # churn actually happened
    assert all((l.bits_per_user > 0).any() for l in res.logs)  # never empty


def test_churn_power_control_excludes_inactive(problem):
    """With churn + power control, absent users must not enter the
    power-control problem: fewer co-scheduled users => each active
    user's rate is no worse than in the full-participation round with
    identical payloads, so the straggler latency stays bounded by the
    full-K solve."""
    train, test, cfg = problem
    K = 8
    shards = partition_iid(train, K)
    chan = make_channel(CFmMIMOConfig(K=K), seed=0)
    fl = FLConfig(L=1, T=5, batch_size=8, alpha=0.02, eval_every=5,
                  seed=0)
    res = VectorizedFLEngine(
        train, test, shards, cfg, ClassicQuantizer(),
        BisectionLPPowerControl(), chan, fl,
        engine=EngineConfig(fused=True, participation=0.5)).run()
    full = VectorizedFLEngine(
        train, test, shards, cfg, ClassicQuantizer(),
        BisectionLPPowerControl(), chan, fl,
        engine=EngineConfig(fused=True)).run()
    # classic quantizer => identical payload per transmitting user, so
    # a churned round (fewer interferers) is never slower than full
    for lc, lf in zip(res.logs, full.logs):
        if (lc.bits_per_user == 0).any():
            assert lc.uplink_latency_s <= lf.uplink_latency_s * (1 + 1e-9)


def test_monte_carlo_channel_redraw_changes_latency(problem):
    """Per-round channel redraws produce varying uplink latencies at
    constant payload (classic quantizer => bits constant)."""
    train, test, cfg = problem
    K = 4
    shards = partition_iid(train, K)
    chan = make_channel(CFmMIMOConfig(K=K), seed=0)
    fl = FLConfig(L=1, T=4, batch_size=8, alpha=0.02, eval_every=4,
                  seed=0)
    res = VectorizedFLEngine(
        train, test, shards, cfg, ClassicQuantizer(),
        BisectionLPPowerControl(), chan, fl,
        engine=EngineConfig(fused=True, redraw_channel_every=1)).run()
    uplinks = [l.uplink_latency_s for l in res.logs]
    assert len(set(uplinks)) > 1


def test_partition_powerlaw_sizes():
    full = make_image_classification(n_samples=800, hw=8, n_classes=4,
                                     seed=0)
    shards = partition_powerlaw(full, 8, exponent=1.3, seed=0)
    sizes = [len(s) for s in shards]
    assert sizes[0] > sizes[-1]            # heterogeneous
    cat = np.concatenate(shards)
    assert len(np.unique(cat)) == len(cat)  # disjoint
    assert len(cat) <= len(full)


def test_build_problem_shapes():
    scn = _shrink(get_scenario("paper-table3"))
    train, test, shards, cnn_cfg, chan = build_problem(scn)
    assert len(shards) == scn.K
    assert chan is not None and chan.beta.shape == (scn.M, scn.K)
    assert train.x.shape[1] == cnn_cfg.input_hw
