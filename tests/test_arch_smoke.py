"""Per-architecture smoke tests: REDUCED variant of each assigned
family, one forward + one train step + one decode step on CPU,
asserting shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward, init_cache, init_model,
                          loss_fn, param_count)
from repro.models.config import InputShape
from repro.launch.inputs import input_specs

SMOKE_SHAPE = InputShape("smoke", seq_len=64, global_batch=2, kind="train")


def make_reduced(arch_id):
    cfg = get_config(arch_id).reduced()
    # keep smoke sequences divisible by chunk sizes
    return dataclasses.replace(cfg, ssm_chunk=16)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_train_step(arch_id, rng):
    cfg = make_reduced(arch_id)
    params = init_model(rng, cfg)
    assert param_count(params) > 0
    batch = input_specs(cfg, SMOKE_SHAPE, abstract=False, seed=1)

    logits, mask, aux = forward(params, batch, cfg, remat=False)
    B, S = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg,
                                              remat=False)
    assert np.isfinite(float(loss)) and float(loss) > 0
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0

    # one SGD step decreases nothing catastrophic (finite params)
    new_params = jax.tree_util.tree_map(
        lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(new_params, batch, cfg, remat=False)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step(arch_id, rng):
    cfg = make_reduced(arch_id)
    params = init_model(rng, cfg)
    B, max_len = 2, 32
    cache = init_cache(cfg, B, max_len, jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        from repro.models.transformer import encode
        frames = jnp.zeros((B, cfg.encoder_seq, 128), jnp.float32)
        cache["enc_out"] = encode(params, frames, cfg)
    tokens = jnp.ones((B, 1), jnp.int32)
    logits, new_cache = decode_step(params, cache, tokens,
                                    jnp.asarray(3, jnp.int32), cfg)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache must have changed
    leaves_old = jax.tree_util.tree_leaves(cache)
    leaves_new = jax.tree_util.tree_leaves(new_cache)
    changed = any(not np.array_equal(np.asarray(a), np.asarray(b))
                  for a, b in zip(leaves_old, leaves_new))
    assert changed


@pytest.mark.parametrize("arch_id", ["granite-3-8b", "rwkv6-7b",
                                     "zamba2-7b"])
def test_decode_matches_forward(arch_id, rng):
    """Greedy decode logits == teacher-forced forward logits."""
    cfg = make_reduced(arch_id)
    params = init_model(rng, cfg)
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits_tf, _, _ = forward(params, {"tokens": tokens}, cfg, remat=False)

    cache = init_cache(cfg, B, S, jnp.dtype(cfg.dtype))
    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cache, tokens[:, t:t + 1],
                                jnp.asarray(t, jnp.int32), cfg)
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_tf, np.float32),
        np.asarray(logits_dec, np.float32), rtol=0.15, atol=0.15)


def test_registry_covers_assignment():
    assert len(ARCH_IDS) == 10
    fams = {get_config(a).family for a in ARCH_IDS}
    assert {"dense", "moe", "ssm", "hybrid", "vlm", "audio"} <= fams
    for a in ARCH_IDS:
        assert get_config(a).source  # citation present
