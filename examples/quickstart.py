"""Quickstart: FL with adaptive mixed-resolution quantization + power
control over a CFmMIMO channel (Algorithm 1), on the repro.sim
vectorized engine, in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

Part 1 runs the paper's ours-vs-classic comparison through the engine
directly; part 2 shows the scenario/sweep API that the benchmark
tables are built on.
"""
import dataclasses

from repro.configs.paper_cnn import PaperCNNConfig
from repro.core.channel import CFmMIMOConfig, make_channel
from repro.core.power import BisectionLPPowerControl
from repro.core.quantize import ClassicQuantizer, MixedResolutionQuantizer
from repro.data import make_image_classification, partition_dirichlet
from repro.fl import FLConfig
from repro.sim import (EngineConfig, Scenario, VectorizedFLEngine,
                       run_grid)


def engine_demo():
    """Ours vs classic on one channel realization, engine API."""
    K = 8
    full = make_image_classification(n_samples=2400, hw=16, n_classes=4,
                                     seed=0)
    train = dataclasses.replace(full, x=full.x[:2000], y=full.y[:2000])
    test = dataclasses.replace(full, x=full.x[2000:], y=full.y[2000:])
    cfg = PaperCNNConfig(input_hw=16, n_classes=4)
    shards = partition_dirichlet(train, K, alpha=0.3)
    chan = make_channel(CFmMIMOConfig(K=K), seed=0)
    fl = FLConfig(L=5, T=12, batch_size=48, alpha=0.01, eval_every=4)
    fused = EngineConfig(fused=True)   # one jit step per round

    print("== mixed-resolution (ours) + bisection-LP power control ==")
    ours = VectorizedFLEngine(train, test, shards, cfg,
                              MixedResolutionQuantizer(lambda_=0.05, b=10),
                              BisectionLPPowerControl(), chan, fl,
                              engine=fused).run(verbose=True)

    print("== classic FL (32-bit), same channel ==")
    classic = VectorizedFLEngine(train, test, shards, cfg,
                                 ClassicQuantizer(),
                                 BisectionLPPowerControl(), chan, fl,
                                 engine=fused).run(verbose=True)

    rbar = 100 * (1 - ours.mean_bits() / classic.mean_bits())
    speedup = (classic.logs[-1].cum_latency_s
               / max(ours.logs[-1].cum_latency_s, 1e-9))
    print(f"\ncommunication overhead reduction r-bar = {rbar:.1f}%")
    print(f"high-resolution fraction s = {100 * ours.mean_s():.2f}%")
    print(f"wall-clock (simulated) round-latency speedup = {speedup:.1f}x")
    print(f"final accuracy: ours={ours.final_acc:.3f} "
          f"classic={classic.final_acc:.3f}")


def sweep_demo():
    """Scenario x quantizer sweep — the benchmark-table workflow."""
    scn = Scenario(name="quickstart-churn",
                   description="small churn scenario",
                   dataset="fashion-syn", n_train=800, n_test=200,
                   K=6, T=6, L=2, batch_size=16, participation=0.7)
    results = run_grid(
        [scn],
        quantizers={"ours": ("mixed-resolution",
                             {"lambda_": 0.2, "b": 10}),
                    "classic": ("classic", {})},
        powers={"ours-pc": "bisection-lp"},
        quick=False, out_csv="runs/quickstart_sweep.csv")
    print("\n== sweep results (runs/quickstart_sweep.csv) ==")
    for r in results:
        row = r.row()
        print(f"{row['scenario']:>18s} {row['quantizer']:>8s}: "
              f"acc={row['best_acc']:.3f} "
              f"bits/user={row['mean_bits_per_user']:.2e} "
              f"latency={row['total_latency_s']:.2f}s")


if __name__ == "__main__":
    engine_demo()
    sweep_demo()
