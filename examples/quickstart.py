"""Quickstart: FL with adaptive mixed-resolution quantization + power
control over a CFmMIMO channel (Algorithm 1), in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import numpy as np

from repro.configs.paper_cnn import PaperCNNConfig
from repro.core.channel import CFmMIMOConfig, make_channel
from repro.core.power import BisectionLPPowerControl
from repro.core.quantize import ClassicQuantizer, MixedResolutionQuantizer
from repro.data import make_image_classification, partition_dirichlet
from repro.fl import FLConfig, run_fl


def main():
    K = 8
    full = make_image_classification(n_samples=2400, hw=16, n_classes=4,
                                     seed=0)
    train = dataclasses.replace(full, x=full.x[:2000], y=full.y[:2000])
    test = dataclasses.replace(full, x=full.x[2000:], y=full.y[2000:])
    cfg = PaperCNNConfig(input_hw=16, n_classes=4)
    shards = partition_dirichlet(train, K, alpha=0.3)
    chan = make_channel(CFmMIMOConfig(K=K), seed=0)
    fl = FLConfig(L=5, T=12, batch_size=48, alpha=0.01, eval_every=4)

    print("== mixed-resolution (ours) + bisection-LP power control ==")
    ours = run_fl(train, test, shards, cfg,
                  MixedResolutionQuantizer(lambda_=0.05, b=10),
                  BisectionLPPowerControl(), chan, fl, verbose=True)

    print("== classic FL (32-bit), same channel ==")
    classic = run_fl(train, test, shards, cfg, ClassicQuantizer(),
                     BisectionLPPowerControl(), chan, fl, verbose=True)

    rbar = 100 * (1 - ours.mean_bits() / classic.mean_bits())
    speedup = (classic.logs[-1].cum_latency_s
               / max(ours.logs[-1].cum_latency_s, 1e-9))
    print(f"\ncommunication overhead reduction r-bar = {rbar:.1f}%")
    print(f"high-resolution fraction s = {100 * ours.mean_s():.2f}%")
    print(f"wall-clock (simulated) round-latency speedup = {speedup:.1f}x")
    print(f"final accuracy: ours={ours.final_acc:.3f} "
          f"classic={classic.final_acc:.3f}")


if __name__ == "__main__":
    main()
