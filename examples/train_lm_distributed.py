"""End-to-end LM training with the quantized delta-aggregation runtime.

Runs the REAL distributed train step (shard_map replicas + mixed-
resolution compressed aggregation) on whatever devices exist — on this
CPU container that is a 1x1 mesh, on a TPU slice the same script uses
the full mesh.  Trains a small decoder on a synthetic Markov token
stream and reports loss + simulated wire traffic; a --preset=100m
configuration matches the deliverable's "~100M model, few hundred
steps" for real hardware.

    PYTHONPATH=src python examples/train_lm_distributed.py \
        --steps 60 --preset tiny
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import save_checkpoint
from repro.data import TokenBatcher, make_token_stream, prefetch
from repro.dist import (CompressorConfig, TrainHParams, build_train_step,
                        microbatch, train_input_shardings)
from repro.models import init_model
from repro.models.config import InputShape, ModelConfig

PRESETS = {
    "tiny": dict(num_layers=4, d_model=256, d_ff=704, vocab_size=2048,
                 num_heads=4, num_kv_heads=2, head_dim=64),
    "100m": dict(num_layers=12, d_model=768, d_ff=2048, vocab_size=32768,
                 num_heads=12, num_kv_heads=4, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--compressor", default="mixed",
                    choices=["mixed", "none"])
    ap.add_argument("--ckpt-dir", default="runs/lm_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.preset}", family="dense",
                      **PRESETS[args.preset])
    nd = jax.device_count()
    dm = 1
    mesh = jax.make_mesh((nd // dm, dm), ("data", "model"))
    shape = InputShape("train", seq_len=args.seq,
                       global_batch=args.batch, kind="train")
    hp = TrainHParams(L_local=1, alpha=5e-3,
                      compressor=CompressorConfig(kind=args.compressor,
                                                  s_budget=0.02, bits=8,
                                                  exact_topk=True),
                      remat=False)
    params = init_model(jax.random.PRNGKey(0), cfg)
    n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, mesh {dict(mesh.shape)}")

    step = build_train_step(cfg, mesh, shape, hp)
    stream = make_token_stream(args.batch * (args.seq + 1) * 200,
                               cfg.vocab_size, seed=0)
    batcher = prefetch(iter(
        b for _ in range(100) for b in TokenBatcher(
            stream, args.batch, args.seq)), depth=2)

    b0 = microbatch({"tokens": jnp.zeros((args.batch, args.seq),
                                         jnp.int32)}, hp.L_local)
    ps, bs = train_input_shardings(cfg, mesh, shape, params, b0)
    jstep = jax.jit(step, in_shardings=(ps, bs))

    t0 = time.time()
    for i in range(args.steps):
        host = next(batcher)
        batch = microbatch({"tokens": jnp.asarray(host["tokens"])},
                           hp.L_local)
        params, metrics = jstep(params, batch)
        if i % 10 == 0 or i == args.steps - 1:
            wire = float(metrics["wire_bits_per_replica"]) / 8e6
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"wire={wire:.2f}MB/replica "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    save_checkpoint(args.ckpt_dir, args.steps, params,
                    metadata={"preset": args.preset})
    print(f"saved checkpoint to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
