"""Batched serving demo: prefill a batch of prompts, then decode with
the static KV cache through the real serve_step path (the same code the
decode dry-runs lower for the production mesh).

    PYTHONPATH=src python examples/serve_batched.py --arch granite-3-8b
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import decode_step, forward, init_cache, init_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              ssm_chunk=16)
    print(f"arch={cfg.name} (reduced for CPU) pattern={cfg.block_pattern}")
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    max_len = P + args.gen
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, P)), jnp.int32)

    cache = init_cache(cfg, B, max_len, jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        from repro.models.transformer import encode
        frames = jnp.zeros((B, cfg.encoder_seq, 128), jnp.float32)
        cache["enc_out"] = encode(params, frames, cfg)

    # prefill: token-by-token here (a fused prefill path is what the
    # prefill_32k dry-run lowers at scale)
    jit_step = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg))
    t0 = time.time()
    logits = None
    for t in range(P):
        logits, cache = jit_step(params, cache, prompts[:, t:t + 1],
                                 jnp.asarray(t, jnp.int32))
    print(f"prefill: {P} tokens x {B} seqs in {time.time()-t0:.2f}s")

    t0 = time.time()
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
    generated = [tok]
    for t in range(P, max_len - 1):
        logits, cache = jit_step(params, cache, tok.astype(jnp.int32),
                                 jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None]
        generated.append(tok)
    dt = time.time() - t0
    n = len(generated) * B
    print(f"decode: {n} tokens in {dt:.2f}s -> {n/dt:.1f} tok/s (CPU, "
          f"interpret-level perf; see dry-run roofline for TPU)")
    out = jnp.concatenate(generated, axis=1)
    print("sample token ids:", np.asarray(out[0])[:12])


if __name__ == "__main__":
    main()
